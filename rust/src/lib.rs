//! # dpp — data preprocessing pipeline framework + testbed simulator
//!
//! Reproduction of *"Understand Data Preprocessing for Effective
//! End-to-End Training of Deep Neural Networks"* (Gong et al., 2023).
//!
//! Three-layer architecture (see DESIGN.md):
//! * **L3 (this crate)** — the coordinator: storage, codec entropy stage,
//!   staged preprocessing pipeline with placement control, PJRT runtime,
//!   trainer, metrics, the testbed simulator, and the auto-configurator.
//! * **L2 (python/compile/model.py)** — JAX compute graphs (tiny CNNs,
//!   fused preprocessing), AOT-lowered once to `artifacts/*.hlo.txt`.
//! * **L1 (python/compile/kernels/)** — Pallas kernels: dequant+IDCT
//!   decode and fused augmentation.
//!
//! Python never runs on the request path; the `dpp` binary is
//! self-contained once `make artifacts` has produced the HLO files.

// Satellite of the concurrency-correctness PR: every `unsafe` block in
// the crate must carry a `// SAFETY:` comment.  `dpp audit` enforces the
// same rule (plus `// ordering:` on relaxed atomics) without clippy, so
// the invariant holds in plain-cargo environments too.
#![deny(clippy::undocumented_unsafe_blocks)]

/// The `dpp --help` text.  Lives in the library (not the binary) so the
/// help-vs-`apply_args` drift test in `config.rs` can assert that every
/// accepted run flag is documented here.
pub const CLI_HELP: &str = r#"dpp — data preprocessing pipeline framework

USAGE: dpp <subcommand> [--key value ...]

SUBCOMMANDS
  gen-data   --data-dir D [--images N] [--classes K] [--quality Q] [--shards S]
  run        --data-dir D [--model M] [--method raw|record]
             [--placement cpu|hybrid|hybrid0]
             [--storage local|ebs|nvme|dram|s3|s3-cold]
             [--net-conns N] [--readahead-mb M] (remote-tier prefetcher)
             [--epochs E] [--cache-mb M] (raw-byte DRAM cache)
             [--prep-cache-mb M] [--prep-cache-policy lru|minio]
             (decoded-sample cache: epoch >= 2 skips read+decode;
              minio = eviction-free, shuffle-proof hit rate)
             [--fused-decode on|off] (default on: entropy-skip blocks
              outside the crop, IDCT only what training consumes —
              bit-exact vs full decode on cpu/hybrid0 paths)
             [--decode-scale auto|1|2|4|8] (default 1: cap on the
              fractional IDCT scale; auto picks the largest 1/2^k
              with crop/2^k >= out — a quality trade-off you opt
              into, tolerance-checked, cpu path only)
             [--workers auto|N] (elastic CPU-stage pool: auto scales
              between --workers-min and --workers-max from live
              backpressure — add on batcher starvation, park on
              worker starvation/blocking; N pins a fixed pool)
             [--workers-min A] [--workers-max B] (auto pool bounds)
             [--workers-interval S] (controller decision period, secs)
             [--slab-pool auto|N|off] (default auto, cpu placement:
              pooled batch slabs — workers write augmented output
              straight into their batch slot, collate becomes a seal,
              drained batches recycle their arena; N bounds the idle
              arenas kept; off restores the per-sample Vec path for A/B)
             [--simd on|off|auto] (default auto: vectorized IDCT /
              resize+normalize / table-driven entropy kernels at the
              best ISA the CPU reports (AVX2 > SSE2 > scalar); off pins
              the scalar reference kernels; outputs are bit-identical
              either way, so this is purely a speed A/B)
             [--trace PATH|off] (default off: per-stage span tracing,
              written as Chrome trace-event JSON — open in Perfetto or
              chrome://tracing; one track per pipeline thread plus
              queue-depth counter tracks; also fills the report's
              per-stage latency histograms)
             [--trace-sample-rate R] (default 1.0: keep every
              1/R-strided span per (thread, stage); lower it on long
              runs to bound ring memory without losing coverage)
             [--faults off|SPEC] (default off: seeded fault injection on
              the storage tier — SPEC is key=value pairs `transient=P,
              throttle=P,burst=N,straggler=P,slowdown=X,corrupt=P,
              seed=S`; same seed replays the same faults, so a failing
              chaos run is a reproducible bug report)
             [--retries N] (default 3: per-read retry budget with
              exponential backoff + decorrelated jitter; 0 disables)
             [--retry-deadline S] (default 30: per-request wall-clock
              deadline across all attempts)
             [--hedge on|off] (default on: re-issue straggling prefetch
              parts through the window; first response wins)
             [--max-skip-rate R] (default 0: graceful degradation —
              quarantine up to R x expected samples that are
              undecodable (bit flips, exhausted retries, worker
              panics) instead of failing; one skip past the budget
              fails the run loudly, naming what was quarantined)
             [--queue-depth Q] [--time-scale T] [--lr R] [--seed S]
             [--artifacts DIR] [--report-json PATH]
             [--steps N] [--batch B] [--ideal] [--no-train]
  sim        --model M [--gpus G] [--vcpus V] [--method ..] [--placement ..]
             [--storage ..] [--net-conns N] [--seconds S]
             [--prep-cache-gb G] [--prep-cache-policy lru|minio]
             [--fused-decode on|off] [--decode-scale 1|2|4|8]
             [--slab-pool on|off] (model the zero-copy engine: the
              transform share thins by the collate-copy fraction)
             [--simd on|off] (model the SIMD kernels: the entropy,
              transform, and resize+normalize shares thin by the
              bench-calibrated speedups in sim/calib.rs)
             [--fault-rate P] (model a transient-fault rate: the
              storage ceiling thins by (1-P) — expected attempts per
              delivered read are 1/(1-P))
             [--trace-json PATH] (write the DES's synthetic span
              timeline in the same Chrome trace format as `run --trace`)
  serve      --scenario FILE (long-lived multi-tenant service: N jobs
             share one prep cache and one elastic pool; the scenario
             file lists `name=.. items=.. demand=.. epochs=.. join=..`
             job lines plus tier settings, `dpp --help` drift-tested)
             [--goodput-floor F] (default 0.5: admission control — a
              job is admitted only if the cost model predicts every
              admitted job keeps >= F x its standalone goodput;
              otherwise it is rejected loudly, never silently degraded)
             [--quotas on|off] (default on: per-job byte quotas on the
              shared prep cache, rebalanced on join/leave — one job's
              shuffle order cannot evict another's working set; off
              shares one unpartitioned pool for A/B)
             [--cache-mb M] [--workers-min A] [--workers-max B]
             [--prep-cache-policy lru|minio] [--seed S]
             [--report-json PATH] (per-job sections, schema v4)
  reproduce  --fig 2|3|4|5|6|t1 (same harnesses as `cargo bench`)
  autoconf   --model M [--objective throughput|cost] [--budget $/h]
  bench      decode  [--out BENCH_decode.json] (counter-based decode
             microbench: blocks IDCT'd + ns/image per path)
  bench      workers [--out BENCH_workers.json] (fig-5-style fixed
             1/2/4/8 workers vs `auto` per storage tier, analytic
             model — deterministic, no wall clock)
  bench      alloc   [--out BENCH_alloc.json] (counting-allocator
             microbench: allocations/sample + ns/sample, slab vs Vec
             hot path; fails if the slab path regresses >10% over the
             committed allocations/sample baseline)
  bench      trace-overhead [--out BENCH_trace.json] (span-tracing cost
             microbench: ns/sample untraced vs full-rate traced; fails
             if tracing costs more than the committed 3% limit, plus
             exact span/drop accounting gates)
  bench      simd [--out BENCH_simd.json] (SIMD kernel microbench:
             ns/block scaled IDCT + entropy decode, ns/pixel fused
             resize+normalize and normalize, scalar vs best detected
             ISA; asserts bit identity before timing and, under AVX2,
             gates IDCT and normalize at >=2x scalar with a +10% band
             over the committed-baseline speedups)
  bench      chaos [--out BENCH_chaos.json] (fault-injection smoke: a
             record shard streamed through the seeded fault layer under
             retry+hedging at a sweep of transient rates; gates that 1%
             faults complete with <=10% goodput overhead and that a
             retries-off failure replays identically per seed — all
             counter-based, no wall clock)
  bench      serve [--out BENCH_serve.json] (multi-tenant churn smoke:
             a 3-job scenario with mid-epoch join/leave and seeded
             faults through the serve engine; counter-based gates that
             quotas hold the victim's hit rate, the over-demand job is
             rejected by admission control, and the faulty job fails
             alone — deterministic, no wall clock)
  trace      <run.json> (pretty-print the per-stage latency histograms
             and the fetch/prep/compute stall attribution from a report
             saved with `run --report-json`)
  audit      (source-scanning invariant linter: SAFETY comments on
             unsafe blocks, ordering justifications on relaxed atomics,
             poison justifications on mutex lock-unwraps, flag parity
             across CLI_HELP/DESIGN.md, run-report JSON field parity;
             prints file:line findings, exits nonzero on any — the same
             rules `cargo test` enforces, CLI-shaped for CI logs)
  inspect    [--artifacts DIR]
"#;

pub mod audit;
pub mod autoconf;
pub mod bench;
pub mod codec;
pub mod config;
pub mod coordinator;
pub mod dataset;
pub mod metrics;
pub mod nlp;
pub mod ops;
pub mod pipeline;
pub mod record;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod simd;
pub mod storage;
pub mod testing;
pub mod trainer;
pub mod util;
