//! # dpp — data preprocessing pipeline framework + testbed simulator
//!
//! Reproduction of *"Understand Data Preprocessing for Effective
//! End-to-End Training of Deep Neural Networks"* (Gong et al., 2023).
//!
//! Three-layer architecture (see DESIGN.md):
//! * **L3 (this crate)** — the coordinator: storage, codec entropy stage,
//!   staged preprocessing pipeline with placement control, PJRT runtime,
//!   trainer, metrics, the testbed simulator, and the auto-configurator.
//! * **L2 (python/compile/model.py)** — JAX compute graphs (tiny CNNs,
//!   fused preprocessing), AOT-lowered once to `artifacts/*.hlo.txt`.
//! * **L1 (python/compile/kernels/)** — Pallas kernels: dequant+IDCT
//!   decode and fused augmentation.
//!
//! Python never runs on the request path; the `dpp` binary is
//! self-contained once `make artifacts` has produced the HLO files.

pub mod autoconf;
pub mod bench;
pub mod codec;
pub mod config;
pub mod coordinator;
pub mod dataset;
pub mod metrics;
pub mod nlp;
pub mod ops;
pub mod pipeline;
pub mod record;
pub mod runtime;
pub mod sim;
pub mod storage;
pub mod testing;
pub mod trainer;
pub mod util;
