//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from the
//! rust hot path (the "accelerator" of this testbed).
//!
//! Interchange is HLO *text* (`artifacts/*.hlo.txt`, see aot.py) compiled
//! on a `PjRtClient::cpu()`.  Every artifact is lowered with
//! `return_tuple=True`, so execution yields one tuple buffer which we
//! sync-copy to host and decompose.  The engine is deliberately
//! single-threaded (wrapped types hold raw PJRT pointers): the pipeline
//! gives it a dedicated *device thread*, which doubles as the contention
//! model — preprocessing offload and training steps share the device,
//! exactly the GPU-sharing effect the paper measures (§3.2, Fig. 5).

pub mod manifest;

pub use manifest::{ArgSpec, ArtifactSpec, DType, Manifest, ModelSpec};

use anyhow::{ensure, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;
use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable};

/// Wall-time accounting of device activity (feeds GPU-utilization metrics).
#[derive(Debug, Default, Clone, Copy)]
pub struct DeviceStats {
    pub executions: u64,
    pub busy_secs: f64,
    pub compile_secs: f64,
}

pub struct Engine {
    client: PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: HashMap<String, PjRtLoadedExecutable>,
    stats: DeviceStats,
}

impl Engine {
    /// Create a CPU-PJRT engine over an artifact directory.
    pub fn new(artifact_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Engine {
            client,
            dir: artifact_dir.to_path_buf(),
            manifest,
            cache: HashMap::new(),
            stats: DeviceStats::default(),
        })
    }

    pub fn stats(&self) -> DeviceStats {
        self.stats
    }

    /// Compile (and cache) an artifact by manifest name.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.dir.join(&spec.file);
        let t = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("path utf8")?)
            .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        self.stats.compile_secs += t.elapsed().as_secs_f64();
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with host literals; returns decomposed outputs.
    pub fn execute(&mut self, name: &str, args: &[Literal]) -> Result<Vec<Literal>> {
        self.load(name)?;
        let spec = self.manifest.artifact(name)?;
        ensure!(
            args.len() == spec.args.len(),
            "{name}: got {} args, artifact wants {}",
            args.len(),
            spec.args.len()
        );
        let exe = self.cache.get(name).unwrap();
        let t = Instant::now();
        let out = exe
            .execute::<Literal>(args)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let mut lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {name}: {e:?}"))?;
        self.stats.busy_secs += t.elapsed().as_secs_f64();
        self.stats.executions += 1;
        lit.decompose_tuple().map_err(|e| anyhow::anyhow!("untuple {name}: {e:?}"))
    }

    /// Load a model's initial parameters from `params_<model>.bin`.
    pub fn load_params(&self, model: &str) -> Result<Vec<Literal>> {
        let spec = self.manifest.model(model)?;
        let blob = std::fs::read(self.dir.join(&spec.param_file))
            .with_context(|| format!("read {}", spec.param_file))?;
        let mut out = Vec::with_capacity(spec.leaves.len());
        for leaf in &spec.leaves {
            ensure!(
                leaf.offset + leaf.bytes <= blob.len(),
                "param blob too short for {}",
                leaf.name
            );
            let bytes = &blob[leaf.offset..leaf.offset + leaf.bytes];
            out.push(lit_f32_bytes(&leaf.shape, bytes)?);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Literal construction helpers
// ---------------------------------------------------------------------------

/// f32 literal from raw little-endian bytes.
pub fn lit_f32_bytes(shape: &[usize], bytes: &[u8]) -> Result<Literal> {
    ensure!(
        bytes.len() == shape.iter().product::<usize>() * 4,
        "shape {shape:?} wants {} bytes, got {}",
        shape.iter().product::<usize>() * 4,
        bytes.len()
    );
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::F32, shape, bytes)
        .map_err(|e| anyhow::anyhow!("literal: {e:?}"))?)
}

/// f32 literal from a slice.
pub fn lit_f32(shape: &[usize], data: &[f32]) -> Result<Literal> {
    ensure!(
        data.len() == shape.iter().product::<usize>(),
        "shape {shape:?} wants {} elems, got {}",
        shape.iter().product::<usize>(),
        data.len()
    );
    // SAFETY: viewing `data`'s f32s as raw bytes — the pointer is valid
    // for `data.len() * 4` bytes (size_of::<f32>() == 4), u8 has
    // alignment 1 ≤ align_of::<f32>(), f32 has no padding or invalid bit
    // patterns, and the borrow of `data` outlives `bytes`.
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    lit_f32_bytes(shape, bytes)
}

/// i32 literal from a slice.
pub fn lit_i32(shape: &[usize], data: &[i32]) -> Result<Literal> {
    ensure!(data.len() == shape.iter().product::<usize>(), "shape/elems mismatch");
    // SAFETY: viewing `data`'s i32s as raw bytes — valid for
    // `data.len() * 4` bytes, u8 alignment 1 ≤ align_of::<i32>(), i32
    // has no padding or invalid bit patterns, borrow outlives `bytes`.
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::S32, shape, bytes)
        .map_err(|e| anyhow::anyhow!("literal: {e:?}"))?)
}

/// Scalar f32 literal.
pub fn lit_scalar(v: f32) -> Literal {
    Literal::scalar(v)
}

/// Copy a literal out as f32s.
pub fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifact_dir().join("manifest.json").exists()
    }

    #[test]
    fn literal_helpers_roundtrip() {
        let l = lit_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(to_vec_f32(&l).unwrap(), vec![1., 2., 3., 4., 5., 6.]);
        assert!(lit_f32(&[2, 2], &[1.0]).is_err());
        let i = lit_i32(&[3], &[7, 8, 9]).unwrap();
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![7, 8, 9]);
    }

    #[test]
    fn engine_executes_decode_artifact() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut eng = Engine::new(&artifact_dir()).unwrap();
        let spec = eng.manifest.artifact("decode_b8").unwrap().clone();
        let n: usize = spec.args[0].elems();
        // All-zero coefficients decode to mid-gray 128.
        let coefs = lit_f32(&spec.args[0].shape, &vec![0f32; n]).unwrap();
        let q = lit_f32(&[8, 8], &[1f32; 64]).unwrap();
        let outs = eng.execute("decode_b8", &[coefs, q]).unwrap();
        assert_eq!(outs.len(), 1);
        let pix = to_vec_f32(&outs[0]).unwrap();
        assert_eq!(pix.len(), 8 * 3 * 64 * 64);
        assert!(pix.iter().all(|&p| (p - 128.0).abs() < 1e-3));
        assert_eq!(eng.stats().executions, 1);
    }

    #[test]
    fn engine_loads_params_with_manifest_schema() {
        if !have_artifacts() {
            return;
        }
        let eng = Engine::new(&artifact_dir()).unwrap();
        let params = eng.load_params("resnet_t").unwrap();
        let spec = eng.manifest.model("resnet_t").unwrap();
        assert_eq!(params.len(), spec.leaves.len());
        let total: usize = params.iter().map(|p| p.element_count()).sum();
        assert_eq!(total, spec.param_count);
    }
}
