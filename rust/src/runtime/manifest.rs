//! Parse `artifacts/manifest.json` written by `python/compile/aot.py`.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" | "float32" => Ok(DType::F32),
            "i32" | "int32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other}"),
        }
    }

    pub fn size(&self) -> usize {
        4
    }
}

#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl ArgSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub args: Vec<ArgSpec>,
    pub outs: Vec<ArgSpec>,
}

#[derive(Clone, Debug)]
pub struct LeafSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub bytes: usize,
}

#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub param_file: String,
    pub param_count: usize,
    pub leaves: Vec<LeafSpec>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub models: BTreeMap<String, ModelSpec>,
    pub batch_main: usize,
    pub batch_test: usize,
    pub img_hw: usize,
    pub out_hw: usize,
    pub num_classes: usize,
}

fn parse_shape(j: &Json) -> Vec<usize> {
    j.as_arr()
        .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
        .unwrap_or_default()
}

fn parse_arg(j: &Json) -> Result<ArgSpec> {
    Ok(ArgSpec {
        name: j.get("name").and_then(|v| v.as_str()).unwrap_or("").to_string(),
        shape: parse_shape(j.req("shape")),
        dtype: DType::parse(j.req("dtype").as_str().context("dtype not a string")?)?,
    })
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("manifest.json parse")?;
        let mut artifacts = BTreeMap::new();
        for (name, a) in j.req("artifacts").as_obj().context("artifacts")? {
            let args = a
                .req("args")
                .as_arr()
                .context("args")?
                .iter()
                .map(parse_arg)
                .collect::<Result<Vec<_>>>()?;
            let outs = a
                .req("outs")
                .as_arr()
                .context("outs")?
                .iter()
                .map(parse_arg)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: a.req("file").as_str().context("file")?.to_string(),
                    args,
                    outs,
                },
            );
        }
        let mut models = BTreeMap::new();
        for (name, m) in j.req("models").as_obj().context("models")? {
            let leaves = m
                .req("leaves")
                .as_arr()
                .context("leaves")?
                .iter()
                .map(|l| {
                    Ok(LeafSpec {
                        name: l.req("name").as_str().context("leaf name")?.to_string(),
                        shape: parse_shape(l.req("shape")),
                        offset: l.req("offset").as_usize().context("offset")?,
                        bytes: l.req("bytes").as_usize().context("bytes")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            models.insert(
                name.clone(),
                ModelSpec {
                    name: name.clone(),
                    param_file: m.req("param_file").as_str().context("param_file")?.to_string(),
                    param_count: m.req("param_count").as_usize().context("param_count")?,
                    leaves,
                },
            );
        }
        Ok(Manifest {
            artifacts,
            models,
            batch_main: j.req("batch_main").as_usize().context("batch_main")?,
            batch_test: j.req("batch_test").as_usize().context("batch_test")?,
            img_hw: j.req("img_hw").as_usize().context("img_hw")?,
            out_hw: j.req("out_hw").as_usize().context("out_hw")?,
            num_classes: j.req("num_classes").as_usize().context("num_classes")?,
        })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let p = dir.join("manifest.json");
        let text = std::fs::read_to_string(&p)
            .with_context(|| format!("read {p:?} — run `make artifacts` first"))?;
        Self::parse(&text)
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).with_context(|| format!("artifact {name} not in manifest"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models.get(name).with_context(|| format!("model {name} not in manifest"))
    }

    /// Artifact name helpers (naming scheme from aot.py).
    pub fn train_artifact(&self, model: &str, batch: usize) -> String {
        format!("train_{model}_b{batch}")
    }

    pub fn fused_artifact(&self, batch: usize) -> String {
        format!("fused_pre_b{batch}")
    }

    pub fn augment_artifact(&self, batch: usize) -> String {
        format!("augment_b{batch}")
    }

    pub fn decode_artifact(&self, batch: usize) -> String {
        format!("decode_b{batch}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": {
        "decode_b8": {
          "file": "decode_b8.hlo.txt",
          "args": [
            {"name": "coefs", "shape": [8,3,8,8,8,8], "dtype": "f32"},
            {"name": "qtable", "shape": [8,8], "dtype": "f32"}
          ],
          "outs": [{"name": "", "shape": [8,3,64,64], "dtype": "f32"}],
          "sha256": "ab"
        }
      },
      "models": {
        "resnet_t": {
          "param_file": "params_resnet_t.bin",
          "param_count": 100,
          "leaves": [
            {"name": "stem", "shape": [16,3,3,3], "offset": 0, "bytes": 1728}
          ]
        }
      },
      "batch_main": 32, "batch_test": 8,
      "img_hw": 64, "out_hw": 56, "num_classes": 16,
      "param_seed": 42, "format": 1
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.artifact("decode_b8").unwrap();
        assert_eq!(a.args.len(), 2);
        assert_eq!(a.args[0].shape, vec![8, 3, 8, 8, 8, 8]);
        assert_eq!(a.args[0].elems(), 8 * 3 * 8 * 8 * 8 * 8);
        assert_eq!(a.outs[0].shape, vec![8, 3, 64, 64]);
        let model = m.model("resnet_t").unwrap();
        assert_eq!(model.leaves[0].bytes, 1728);
        assert_eq!(m.batch_main, 32);
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.artifacts.len() >= 10);
            assert!(m.models.contains_key("resnet_t"));
        }
    }

    #[test]
    fn artifact_name_helpers() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.train_artifact("resnet_t", 32), "train_resnet_t_b32");
        assert_eq!(m.fused_artifact(8), "fused_pre_b8");
    }
}
