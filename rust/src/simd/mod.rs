//! SIMD kernel layer for the four hottest preprocessing loops (§Perf,
//! DESIGN.md "SIMD kernels"): the 8/4-point scaled IDCT, the fused
//! bilinear-sample+normalize row, the normalize copy, and (via
//! `codec/entropy.rs`) the table-driven entropy decode.
//!
//! Dispatch strategy: `std::arch` x86-64 intrinsics with SSE2 as the
//! baseline tier (architecturally guaranteed on x86_64, no runtime
//! check) and AVX2 selected by `is_x86_feature_detected!` once per
//! process.  The scalar code stays the portable fallback — every other
//! target, miri, and `--simd off` — and the A/B reference.
//!
//! **Bit-identity policy**: every vector kernel performs the *same*
//! per-lane f32 operations in the *same* order as its scalar reference —
//! separate multiply and add intrinsics (no FMA contraction), identical
//! accumulation order, identical zero-row masks — so outputs are
//! bit-identical (`assert_eq!`, not tolerance) across Scalar/Sse2/Avx2.
//! That invariant is what makes the process-global mode switch benign:
//! a thread racing `set_mode` can only ever observe a level whose output
//! is bit-for-bit the same.  `tests/simd_kernels.rs` is the enforcing
//! property harness.
//!
//! Intrinsic paths are gated `#[cfg(all(target_arch = "x86_64",
//! not(miri)))]`: miri cannot execute vendor intrinsics, so under miri
//! (and on every non-x86 target) `detect()` reports `Scalar` and the
//! dispatch/fallback logic itself stays checkable.

use anyhow::{bail, Result};
use once_cell::sync::Lazy;
use std::sync::atomic::{AtomicU8, Ordering};

/// Instruction-set tier a kernel call runs at.  Ordered: a level only
/// ever *adds* lanes, so clamping with `min(detect())` is always sound.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SimdLevel {
    Scalar = 0,
    Sse2 = 1,
    Avx2 = 2,
}

impl SimdLevel {
    pub fn name(&self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Sse2 => "sse2",
            SimdLevel::Avx2 => "avx2",
        }
    }
}

/// The `--simd` flag: `off` pins the scalar reference path, `on` and
/// `auto` both resolve to the best runtime-detected ISA (`on` is the
/// explicit A/B spelling; on a target with no SIMD tier it still
/// resolves to scalar rather than failing).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SimdMode {
    Off,
    On,
    #[default]
    Auto,
}

impl SimdMode {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "off" => SimdMode::Off,
            "on" => SimdMode::On,
            "auto" => SimdMode::Auto,
            _ => bail!("--simd must be on|off|auto, got {s}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SimdMode::Off => "off",
            SimdMode::On => "on",
            SimdMode::Auto => "auto",
        }
    }
}

fn detect_uncached() -> SimdLevel {
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return SimdLevel::Avx2;
        }
        // SSE2 is part of the x86-64 baseline ABI.
        SimdLevel::Sse2
    }
    #[cfg(not(all(target_arch = "x86_64", not(miri))))]
    SimdLevel::Scalar
}

static DETECTED: Lazy<SimdLevel> = Lazy::new(detect_uncached);

/// Best ISA tier this CPU supports (cached; `Scalar` under miri and on
/// non-x86-64 targets).
pub fn detect() -> SimdLevel {
    *DETECTED
}

const LEVEL_UNSET: u8 = 0xFF;

/// Process-wide active level, set once by the coordinator from the
/// `--simd` flag.  Safe to read from any worker at any time because all
/// levels produce bit-identical outputs (see module docs).
static ACTIVE: AtomicU8 = AtomicU8::new(LEVEL_UNSET);

fn level_from_u8(v: u8) -> SimdLevel {
    match v {
        2 => SimdLevel::Avx2,
        1 => SimdLevel::Sse2,
        _ => SimdLevel::Scalar,
    }
}

/// Resolve a mode to the level it pins (pure; `set_mode` stores this).
pub fn resolve_mode(mode: SimdMode) -> SimdLevel {
    match mode {
        SimdMode::Off => SimdLevel::Scalar,
        SimdMode::On | SimdMode::Auto => detect(),
    }
}

/// Install the `--simd` mode for the process (called by
/// `coordinator::run` before any decode work starts).
pub fn set_mode(mode: SimdMode) {
    let level = resolve_mode(mode);
    // ordering: Relaxed — a standalone u8 with no payload to publish;
    // every level yields bit-identical outputs, so a racing reader that
    // observes a stale level is semantically invisible.
    ACTIVE.store(level as u8, Ordering::Relaxed);
}

/// The level hot paths should run at (defaults to `detect()` until
/// `set_mode` is called).
pub fn active() -> SimdLevel {
    // ordering: Relaxed — see `set_mode`; single independent u8.
    match ACTIVE.load(Ordering::Relaxed) {
        LEVEL_UNSET => detect(),
        v => level_from_u8(v),
    }
}

/// Whether the entropy reader should take its table-driven fast path
/// (safe Rust, but A/B-gated with the rest of the SIMD layer so
/// `--simd off` pins the byte-at-a-time reference loop).
pub fn entropy_fast() -> bool {
    active() != SimdLevel::Scalar
}

// ---------------------------------------------------------------------------
// Kernel dispatch
// ---------------------------------------------------------------------------

/// Vectorized fused dequant+IDCT of a full 8×8 block (the scalar
/// reference is `codec::dct::dequant_idct_block_scalar`).  Returns
/// `false` when no vector tier applies — the caller then runs scalar.
pub fn dequant_idct8(
    coef: &[f32; 64],
    q: &[f32; 64],
    c: &[[f32; 8]; 8],
    block: &mut [f32; 64],
    level: SimdLevel,
) -> bool {
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        match level.min(detect()) {
            SimdLevel::Avx2 => {
                // SAFETY: the level is clamped to detect(), which only
                // reports Avx2 after is_x86_feature_detected!("avx2").
                unsafe { x86::dequant_idct8_avx2(coef, q, c, block) };
                return true;
            }
            SimdLevel::Sse2 => {
                x86::dequant_idct8_sse2(coef, q, c, block);
                return true;
            }
            SimdLevel::Scalar => {}
        }
    }
    let _ = (coef, q, c, block, level);
    false
}

/// Vectorized fused dequant + 4-point corner IDCT (scale 1/2; the
/// scalar reference is `codec::dct`'s `idct_corner::<4>`).  `out` must
/// hold 16 values.  Returns `false` when no vector tier applies.
pub fn dequant_idct4(
    coef: &[f32; 64],
    q: &[f32; 64],
    c: &[[f32; 4]; 4],
    out: &mut [f32],
    level: SimdLevel,
) -> bool {
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        if level.min(detect()) >= SimdLevel::Sse2 {
            // One __m128 row covers the whole 4-lane output: the same
            // kernel serves both the Sse2 and Avx2 tiers.
            x86::dequant_idct4_sse2(coef, q, c, out);
            return true;
        }
    }
    let _ = (coef, q, c, out, level);
    false
}

/// One output row of the fused crop+flip+bilinear+normalize sampler:
/// `orow[j] = ((r0[x0]·omwx + r0[x1]·wx)·(1−wy) + (r1[x0]·omwx +
/// r1[x1]·wx)·wy − mean)·istd`, the exact per-lane operation order of
/// the scalar loop in `ops::augment_fused_view_into`.  Complete in
/// itself: dispatches to the best tier ≤ `level` and handles the
/// non-multiple-of-lane tail (and the Scalar tier) with the scalar loop.
#[allow(clippy::too_many_arguments)]
pub fn bilerp_norm_row(
    r0: &[f32],
    r1: &[f32],
    x0: &[i32],
    x1: &[i32],
    wx: &[f32],
    omwx: &[f32],
    wy: f32,
    mean: f32,
    istd: f32,
    orow: &mut [f32],
    level: SimdLevel,
) {
    debug_assert!(x0.len() >= orow.len() && x1.len() >= orow.len());
    debug_assert!(wx.len() >= orow.len() && omwx.len() >= orow.len());
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        match level.min(detect()) {
            SimdLevel::Avx2 => {
                // SAFETY: level clamped to detect(); AVX2 runtime-verified.
                unsafe { x86::bilerp_norm_row_avx2(r0, r1, x0, x1, wx, omwx, wy, mean, istd, orow) };
                return;
            }
            SimdLevel::Sse2 => {
                x86::bilerp_norm_row_sse2(r0, r1, x0, x1, wx, omwx, wy, mean, istd, orow);
                return;
            }
            SimdLevel::Scalar => {}
        }
    }
    let _ = level;
    bilerp_norm_row_scalar(r0, r1, x0, x1, wx, omwx, wy, mean, istd, orow);
}

/// Scalar reference/tail for [`bilerp_norm_row`] — the exact operation
/// sequence of the pre-SIMD `ops::augment_fused_view_into` inner loop
/// (`omwx[j]` carries the `1.0 - wx` the old loop recomputed per row,
/// which is value-identical because f32 subtraction is deterministic).
#[allow(clippy::too_many_arguments)]
pub fn bilerp_norm_row_scalar(
    r0: &[f32],
    r1: &[f32],
    x0: &[i32],
    x1: &[i32],
    wx: &[f32],
    omwx: &[f32],
    wy: f32,
    mean: f32,
    istd: f32,
    orow: &mut [f32],
) {
    let omwy = 1.0 - wy;
    for j in 0..orow.len() {
        let (a, b) = (x0[j] as usize, x1[j] as usize);
        let top = r0[a] * omwx[j] + r0[b] * wx[j];
        let bot = r1[a] * omwx[j] + r1[b] * wx[j];
        let v = top * omwy + bot * wy;
        orow[j] = (v - mean) * istd;
    }
}

/// Lane-parallel in-place normalize: `v = (v − mean)·istd` (the
/// `ops::normalize` inner loop).  Complete with scalar tail/fallback.
pub fn normalize_inplace(buf: &mut [f32], mean: f32, istd: f32, level: SimdLevel) {
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        match level.min(detect()) {
            SimdLevel::Avx2 => {
                // SAFETY: level clamped to detect(); AVX2 runtime-verified.
                unsafe { x86::normalize_inplace_avx2(buf, mean, istd) };
                return;
            }
            SimdLevel::Sse2 => {
                x86::normalize_inplace_sse2(buf, mean, istd);
                return;
            }
            SimdLevel::Scalar => {}
        }
    }
    let _ = level;
    for v in buf {
        *v = (*v - mean) * istd;
    }
}

/// Lane-parallel normalized copy: `dst = (src − mean)·istd` (the
/// `ops::normalize_into` inner loop).  Complete with scalar fallback.
pub fn normalize_copy(src: &[f32], dst: &mut [f32], mean: f32, istd: f32, level: SimdLevel) {
    assert_eq!(src.len(), dst.len());
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        match level.min(detect()) {
            SimdLevel::Avx2 => {
                // SAFETY: level clamped to detect(); AVX2 runtime-verified.
                unsafe { x86::normalize_copy_avx2(src, dst, mean, istd) };
                return;
            }
            SimdLevel::Sse2 => {
                x86::normalize_copy_sse2(src, dst, mean, istd);
                return;
            }
            SimdLevel::Scalar => {}
        }
    }
    let _ = level;
    for (o, &v) in dst.iter_mut().zip(src) {
        *o = (v - mean) * istd;
    }
}

// ---------------------------------------------------------------------------
// x86-64 kernels
// ---------------------------------------------------------------------------

// Newer toolchains mark the statically-enabled-feature intrinsics
// (SSE2 on x86_64) safe, which would flag our `unsafe` blocks as
// unused; older ones require them.  Keep the blocks (and their SAFETY
// comments, which `dpp audit` checks) and silence the skew.
#[cfg(all(target_arch = "x86_64", not(miri)))]
#[allow(unused_unsafe)]
mod x86 {
    use std::arch::x86_64::*;

    /// AVX2 fused dequant+IDCT, 8 lanes per row pass.  Mirrors
    /// `dequant_idct_block_scalar` operation-for-operation: the DC-only
    /// test, the zero-row mask, and both matrix passes accumulate in
    /// the same per-lane order with separate mul+add (no FMA).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dequant_idct8_avx2(
        coef: &[f32; 64],
        q: &[f32; 64],
        c: &[[f32; 8]; 8],
        block: &mut [f32; 64],
    ) {
        // SAFETY: caller runtime-verified AVX2; all loads/stores are
        // unaligned variants on pointers derived from in-bounds ranges
        // of the fixed-size argument arrays.
        unsafe {
            let zero = _mm256_setzero_ps();
            let mut rows = [zero; 8];
            let mut eq = [0i32; 8];
            for k in 0..8 {
                rows[k] = _mm256_loadu_ps(coef.as_ptr().add(k * 8));
                eq[k] = _mm256_movemask_ps(_mm256_cmp_ps::<_CMP_EQ_OQ>(rows[k], zero));
            }
            // DC-only fast path: every AC equals ±0.0 — exactly when the
            // scalar kernel's |AC| sum is 0.0 (a round-to-nearest sum of
            // non-negative f32s cannot round a positive total to zero,
            // and |±0.0| = 0.0), and ±0.0 == 0.0 matches the scalar
            // `v == 0.0` tests.
            if (eq[0] | 1) == 0xFF && eq[1..].iter().all(|&m| m == 0xFF) {
                let v = coef[0] * q[0] * 0.125;
                block.fill(v);
                return;
            }
            // Dequant per row, skipping all-zero rows — the same mask
            // the scalar kernel derives.
            let mut fq = [zero; 8];
            let mut row_mask = 0u8;
            for k in 0..8 {
                if eq[k] == 0xFF {
                    continue;
                }
                row_mask |= 1 << k;
                fq[k] = _mm256_mul_ps(rows[k], _mm256_loadu_ps(q.as_ptr().add(k * 8)));
            }
            // Pass 1: tmp = Cᵀ·fq — broadcast(c[k][i])·row(k) summed in
            // ascending k over the mask.
            let mut tmp = [0f32; 64];
            for i in 0..8 {
                let mut acc = zero;
                for k in 0..8 {
                    if row_mask & (1 << k) == 0 {
                        continue;
                    }
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(c[k][i]), fq[k]));
                }
                _mm256_storeu_ps(tmp.as_mut_ptr().add(i * 8), acc);
            }
            // Pass 2: block = tmp·C — broadcast(tmp[i][k])·C-row(k).
            for i in 0..8 {
                let mut acc = zero;
                for k in 0..8 {
                    acc = _mm256_add_ps(
                        acc,
                        _mm256_mul_ps(_mm256_set1_ps(tmp[i * 8 + k]), _mm256_loadu_ps(c[k].as_ptr())),
                    );
                }
                _mm256_storeu_ps(block.as_mut_ptr().add(i * 8), acc);
            }
        }
    }

    /// SSE2 fused dequant+IDCT: the AVX2 kernel with every 8-lane row
    /// held as two __m128 halves (lanes 0..4 and 4..8); per-lane
    /// operations and order are unchanged.
    pub fn dequant_idct8_sse2(
        coef: &[f32; 64],
        q: &[f32; 64],
        c: &[[f32; 8]; 8],
        block: &mut [f32; 64],
    ) {
        // SAFETY: SSE2 is part of the x86-64 baseline ABI; all
        // loads/stores are unaligned variants on pointers derived from
        // in-bounds ranges of the fixed-size argument arrays.
        unsafe {
            let zero = _mm_setzero_ps();
            let mut lo = [zero; 8];
            let mut hi = [zero; 8];
            let mut eq = [0i32; 8];
            for k in 0..8 {
                lo[k] = _mm_loadu_ps(coef.as_ptr().add(k * 8));
                hi[k] = _mm_loadu_ps(coef.as_ptr().add(k * 8 + 4));
                eq[k] = _mm_movemask_ps(_mm_cmpeq_ps(lo[k], zero))
                    | (_mm_movemask_ps(_mm_cmpeq_ps(hi[k], zero)) << 4);
            }
            // DC-only fast path — see the AVX2 kernel for why the ±0.0
            // equality test matches the scalar |AC|-sum check.
            if (eq[0] | 1) == 0xFF && eq[1..].iter().all(|&m| m == 0xFF) {
                let v = coef[0] * q[0] * 0.125;
                block.fill(v);
                return;
            }
            let mut fq_lo = [zero; 8];
            let mut fq_hi = [zero; 8];
            let mut row_mask = 0u8;
            for k in 0..8 {
                if eq[k] == 0xFF {
                    continue;
                }
                row_mask |= 1 << k;
                fq_lo[k] = _mm_mul_ps(lo[k], _mm_loadu_ps(q.as_ptr().add(k * 8)));
                fq_hi[k] = _mm_mul_ps(hi[k], _mm_loadu_ps(q.as_ptr().add(k * 8 + 4)));
            }
            let mut tmp = [0f32; 64];
            for i in 0..8 {
                let mut alo = zero;
                let mut ahi = zero;
                for k in 0..8 {
                    if row_mask & (1 << k) == 0 {
                        continue;
                    }
                    let a = _mm_set1_ps(c[k][i]);
                    alo = _mm_add_ps(alo, _mm_mul_ps(a, fq_lo[k]));
                    ahi = _mm_add_ps(ahi, _mm_mul_ps(a, fq_hi[k]));
                }
                _mm_storeu_ps(tmp.as_mut_ptr().add(i * 8), alo);
                _mm_storeu_ps(tmp.as_mut_ptr().add(i * 8 + 4), ahi);
            }
            for i in 0..8 {
                let mut alo = zero;
                let mut ahi = zero;
                for k in 0..8 {
                    let t = _mm_set1_ps(tmp[i * 8 + k]);
                    alo = _mm_add_ps(alo, _mm_mul_ps(t, _mm_loadu_ps(c[k].as_ptr())));
                    ahi = _mm_add_ps(ahi, _mm_mul_ps(t, _mm_loadu_ps(c[k].as_ptr().add(4))));
                }
                _mm_storeu_ps(block.as_mut_ptr().add(i * 8), alo);
                _mm_storeu_ps(block.as_mut_ptr().add(i * 8 + 4), ahi);
            }
        }
    }

    /// 4-point corner IDCT, one __m128 per output row.  Mirrors
    /// `idct_corner::<4>`: `acc += (c[u][i]·f[u][v])·c[v][j]` with the
    /// scalar u-major/v-minor accumulation order — hoisting the scalar
    /// product `c[u][i]·f[u][v]` is exact because the scalar expression
    /// parses left-associatively to the same two multiplies.
    pub fn dequant_idct4_sse2(
        coef: &[f32; 64],
        q: &[f32; 64],
        c: &[[f32; 4]; 4],
        out: &mut [f32],
    ) {
        assert_eq!(out.len(), 16, "out must be 4x4");
        // 4/8 basis rescale, exactly the scalar kernel's `N as f32/8.0`.
        let s = 0.5f32;
        let mut f = [[0f32; 4]; 4];
        for u in 0..4 {
            for v in 0..4 {
                f[u][v] = coef[u * 8 + v] * q[u * 8 + v] * s;
            }
        }
        // SAFETY: SSE2 is part of the x86-64 baseline ABI; loads read
        // whole `[f32; 4]` rows and the store targets `out[i*4..i*4+4]`,
        // in bounds per the length assert above.
        unsafe {
            let crows = [
                _mm_loadu_ps(c[0].as_ptr()),
                _mm_loadu_ps(c[1].as_ptr()),
                _mm_loadu_ps(c[2].as_ptr()),
                _mm_loadu_ps(c[3].as_ptr()),
            ];
            for i in 0..4 {
                let mut acc = _mm_setzero_ps();
                for u in 0..4 {
                    for v in 0..4 {
                        acc = _mm_add_ps(acc, _mm_mul_ps(_mm_set1_ps(c[u][i] * f[u][v]), crows[v]));
                    }
                }
                _mm_storeu_ps(out.as_mut_ptr().add(i * 4), acc);
            }
        }
    }

    /// AVX2 fused bilinear+normalize row: gathers the four taps with
    /// `vgatherdps`, then the scalar loop's exact mul/add sequence,
    /// 8 output columns per iteration; scalar tail for the remainder.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    pub unsafe fn bilerp_norm_row_avx2(
        r0: &[f32],
        r1: &[f32],
        x0: &[i32],
        x1: &[i32],
        wx: &[f32],
        omwx: &[f32],
        wy: f32,
        mean: f32,
        istd: f32,
        orow: &mut [f32],
    ) {
        let n = orow.len();
        // SAFETY: caller runtime-verified AVX2.  Gather indices come
        // from the interpolation tables, whose entries are clamped
        // in-bounds for the source rows by `augment_fused_view_into`
        // (x0/x1 < row length); table and output loads/stores stay
        // inside `..n`, within every slice per the dispatch asserts.
        unsafe {
            let wyv = _mm256_set1_ps(wy);
            let omwyv = _mm256_set1_ps(1.0 - wy);
            let meanv = _mm256_set1_ps(mean);
            let istdv = _mm256_set1_ps(istd);
            let mut j = 0usize;
            while j + 8 <= n {
                let ix0 = _mm256_loadu_si256(x0.as_ptr().add(j) as *const __m256i);
                let ix1 = _mm256_loadu_si256(x1.as_ptr().add(j) as *const __m256i);
                let wxv = _mm256_loadu_ps(wx.as_ptr().add(j));
                let omwxv = _mm256_loadu_ps(omwx.as_ptr().add(j));
                let t0 = _mm256_i32gather_ps::<4>(r0.as_ptr(), ix0);
                let t1 = _mm256_i32gather_ps::<4>(r0.as_ptr(), ix1);
                let top = _mm256_add_ps(_mm256_mul_ps(t0, omwxv), _mm256_mul_ps(t1, wxv));
                let b0 = _mm256_i32gather_ps::<4>(r1.as_ptr(), ix0);
                let b1 = _mm256_i32gather_ps::<4>(r1.as_ptr(), ix1);
                let bot = _mm256_add_ps(_mm256_mul_ps(b0, omwxv), _mm256_mul_ps(b1, wxv));
                let v = _mm256_add_ps(_mm256_mul_ps(top, omwyv), _mm256_mul_ps(bot, wyv));
                let o = _mm256_mul_ps(_mm256_sub_ps(v, meanv), istdv);
                _mm256_storeu_ps(orow.as_mut_ptr().add(j), o);
                j += 8;
            }
            super::bilerp_norm_row_scalar(
                r0,
                r1,
                &x0[j..],
                &x1[j..],
                &wx[j..],
                &omwx[j..],
                wy,
                mean,
                istd,
                &mut orow[j..],
            );
        }
    }

    /// SSE2 fused bilinear+normalize row: 4 columns per iteration with
    /// bounds-checked scalar gathers into `_mm_set_ps` lanes; the
    /// arithmetic sequence is the AVX2/scalar one.
    #[allow(clippy::too_many_arguments)]
    pub fn bilerp_norm_row_sse2(
        r0: &[f32],
        r1: &[f32],
        x0: &[i32],
        x1: &[i32],
        wx: &[f32],
        omwx: &[f32],
        wy: f32,
        mean: f32,
        istd: f32,
        orow: &mut [f32],
    ) {
        let n = orow.len();
        // SAFETY: SSE2 is part of the x86-64 baseline ABI; vector
        // loads/stores stay inside `..n` of their slices, and the taps
        // use ordinary bounds-checked slice indexing.
        unsafe {
            let wyv = _mm_set1_ps(wy);
            let omwyv = _mm_set1_ps(1.0 - wy);
            let meanv = _mm_set1_ps(mean);
            let istdv = _mm_set1_ps(istd);
            let mut j = 0usize;
            while j + 4 <= n {
                let g = |row: &[f32], ix: &[i32]| {
                    _mm_set_ps(
                        row[ix[j + 3] as usize],
                        row[ix[j + 2] as usize],
                        row[ix[j + 1] as usize],
                        row[ix[j] as usize],
                    )
                };
                let wxv = _mm_loadu_ps(wx.as_ptr().add(j));
                let omwxv = _mm_loadu_ps(omwx.as_ptr().add(j));
                let top = _mm_add_ps(_mm_mul_ps(g(r0, x0), omwxv), _mm_mul_ps(g(r0, x1), wxv));
                let bot = _mm_add_ps(_mm_mul_ps(g(r1, x0), omwxv), _mm_mul_ps(g(r1, x1), wxv));
                let v = _mm_add_ps(_mm_mul_ps(top, omwyv), _mm_mul_ps(bot, wyv));
                let o = _mm_mul_ps(_mm_sub_ps(v, meanv), istdv);
                _mm_storeu_ps(orow.as_mut_ptr().add(j), o);
                j += 4;
            }
            super::bilerp_norm_row_scalar(
                r0,
                r1,
                &x0[j..],
                &x1[j..],
                &wx[j..],
                &omwx[j..],
                wy,
                mean,
                istd,
                &mut orow[j..],
            );
        }
    }

    /// AVX2 in-place normalize, 8 lanes per iteration + scalar tail.
    #[target_feature(enable = "avx2")]
    pub unsafe fn normalize_inplace_avx2(buf: &mut [f32], mean: f32, istd: f32) {
        let n = buf.len();
        // SAFETY: caller runtime-verified AVX2; unaligned loads/stores
        // stay inside `buf[..n]`.
        unsafe {
            let meanv = _mm256_set1_ps(mean);
            let istdv = _mm256_set1_ps(istd);
            let mut j = 0usize;
            while j + 8 <= n {
                let v = _mm256_loadu_ps(buf.as_ptr().add(j));
                _mm256_storeu_ps(buf.as_mut_ptr().add(j), _mm256_mul_ps(_mm256_sub_ps(v, meanv), istdv));
                j += 8;
            }
            for v in &mut buf[j..] {
                *v = (*v - mean) * istd;
            }
        }
    }

    /// SSE2 in-place normalize, 4 lanes per iteration + scalar tail.
    pub fn normalize_inplace_sse2(buf: &mut [f32], mean: f32, istd: f32) {
        let n = buf.len();
        // SAFETY: SSE2 is part of the x86-64 baseline ABI; unaligned
        // loads/stores stay inside `buf[..n]`.
        unsafe {
            let meanv = _mm_set1_ps(mean);
            let istdv = _mm_set1_ps(istd);
            let mut j = 0usize;
            while j + 4 <= n {
                let v = _mm_loadu_ps(buf.as_ptr().add(j));
                _mm_storeu_ps(buf.as_mut_ptr().add(j), _mm_mul_ps(_mm_sub_ps(v, meanv), istdv));
                j += 4;
            }
            for v in &mut buf[j..] {
                *v = (*v - mean) * istd;
            }
        }
    }

    /// AVX2 normalized copy, 8 lanes per iteration + scalar tail.
    #[target_feature(enable = "avx2")]
    pub unsafe fn normalize_copy_avx2(src: &[f32], dst: &mut [f32], mean: f32, istd: f32) {
        let n = dst.len();
        // SAFETY: caller runtime-verified AVX2 and asserted equal
        // lengths; unaligned loads/stores stay inside `..n`.
        unsafe {
            let meanv = _mm256_set1_ps(mean);
            let istdv = _mm256_set1_ps(istd);
            let mut j = 0usize;
            while j + 8 <= n {
                let v = _mm256_loadu_ps(src.as_ptr().add(j));
                _mm256_storeu_ps(dst.as_mut_ptr().add(j), _mm256_mul_ps(_mm256_sub_ps(v, meanv), istdv));
                j += 8;
            }
            for (o, &v) in dst[j..].iter_mut().zip(&src[j..]) {
                *o = (v - mean) * istd;
            }
        }
    }

    /// SSE2 normalized copy, 4 lanes per iteration + scalar tail.
    pub fn normalize_copy_sse2(src: &[f32], dst: &mut [f32], mean: f32, istd: f32) {
        let n = dst.len();
        // SAFETY: SSE2 is part of the x86-64 baseline ABI; the caller
        // asserted equal lengths; unaligned loads/stores stay in `..n`.
        unsafe {
            let meanv = _mm_set1_ps(mean);
            let istdv = _mm_set1_ps(istd);
            let mut j = 0usize;
            while j + 4 <= n {
                let v = _mm_loadu_ps(src.as_ptr().add(j));
                _mm_storeu_ps(dst.as_mut_ptr().add(j), _mm_mul_ps(_mm_sub_ps(v, meanv), istdv));
                j += 4;
            }
            for (o, &v) in dst[j..].iter_mut().zip(&src[j..]) {
                *o = (v - mean) * istd;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Every tier at or below `detect()` that has vector lanes.
    pub fn vector_levels() -> Vec<SimdLevel> {
        [SimdLevel::Sse2, SimdLevel::Avx2]
            .into_iter()
            .filter(|&l| l <= detect())
            .collect()
    }

    #[test]
    fn mode_parse_and_names() {
        for (s, m) in [("off", SimdMode::Off), ("on", SimdMode::On), ("auto", SimdMode::Auto)] {
            assert_eq!(SimdMode::parse(s).unwrap(), m);
            assert_eq!(m.name(), s);
        }
        assert!(SimdMode::parse("fast").is_err());
        assert!(SimdMode::parse("").is_err());
    }

    #[test]
    fn mode_resolution_is_clamped_and_off_is_scalar() {
        assert_eq!(resolve_mode(SimdMode::Off), SimdLevel::Scalar);
        assert_eq!(resolve_mode(SimdMode::On), detect());
        assert_eq!(resolve_mode(SimdMode::Auto), detect());
        // The active level is always executable on this CPU.
        assert!(active() <= detect());
    }

    #[test]
    fn detect_is_scalar_under_miri_and_at_least_sse2_on_x86_64() {
        if cfg!(miri) || !cfg!(target_arch = "x86_64") {
            assert_eq!(detect(), SimdLevel::Scalar);
        } else {
            assert!(detect() >= SimdLevel::Sse2);
        }
        assert!(SimdLevel::Scalar < SimdLevel::Sse2 && SimdLevel::Sse2 < SimdLevel::Avx2);
    }

    #[test]
    fn normalize_kernels_bit_identical_across_levels_and_odd_tails() {
        let mut rng = Rng::new(71);
        for n in [0usize, 1, 3, 4, 7, 8, 9, 31, 56 * 56 + 5] {
            let src: Vec<f32> = (0..n).map(|_| rng.uniform(-300.0, 300.0) as f32).collect();
            let (mean, istd) = (123.675f32, 1.0 / 58.395f32);
            let mut want = vec![0f32; n];
            normalize_copy(&src, &mut want, mean, istd, SimdLevel::Scalar);
            for level in vector_levels() {
                let mut got = vec![0f32; n];
                normalize_copy(&src, &mut got, mean, istd, level);
                assert_eq!(want, got, "copy n={n} {level:?}");
                let mut buf = src.clone();
                normalize_inplace(&mut buf, mean, istd, level);
                assert_eq!(want, buf, "inplace n={n} {level:?}");
            }
        }
    }

    #[test]
    fn bilerp_row_bit_identical_across_levels_and_odd_widths() {
        let mut rng = Rng::new(72);
        let vw = 61usize;
        let r0: Vec<f32> = (0..vw).map(|_| rng.uniform(0.0, 255.0) as f32).collect();
        let r1: Vec<f32> = (0..vw).map(|_| rng.uniform(0.0, 255.0) as f32).collect();
        for ow in [1usize, 2, 5, 7, 8, 9, 13, 16, 17, 56] {
            let mut x0 = Vec::new();
            let mut x1 = Vec::new();
            let mut wx = Vec::new();
            let mut omwx = Vec::new();
            for _ in 0..ow {
                let a = rng.gen_range(vw as u64) as i32;
                x0.push(a);
                x1.push((a + 1).min(vw as i32 - 1));
                let f = rng.uniform(0.0, 1.0) as f32;
                wx.push(f);
                omwx.push(1.0 - f);
            }
            let wy = rng.uniform(0.0, 1.0) as f32;
            let (mean, istd) = (116.28f32, 1.0 / 57.12f32);
            let mut want = vec![0f32; ow];
            bilerp_norm_row(&r0, &r1, &x0, &x1, &wx, &omwx, wy, mean, istd, &mut want, SimdLevel::Scalar);
            for level in vector_levels() {
                let mut got = vec![0f32; ow];
                bilerp_norm_row(&r0, &r1, &x0, &x1, &wx, &omwx, wy, mean, istd, &mut got, level);
                assert_eq!(want, got, "ow={ow} {level:?}");
            }
        }
    }
}
