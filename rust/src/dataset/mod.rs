//! Dataset substrate: metadata file, synthetic labeled corpus generator,
//! record-shard builder, and the epoch sampler (Fig. 1 steps ❶–❷ / ①–④).
//!
//! The paper trains on ImageNet; offline we generate a synthetic corpus
//! whose images carry a *learnable* class signal (class-dependent stripe
//! frequency/phase/channel plus noise) so the end-to-end example can show
//! a falling loss curve through the real pipeline.

use crate::codec;
use crate::record::ShardWriter;
use crate::storage::{DirStore, Storage};
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;

/// One metadata tuple: (index, label, path) — the paper's step ❶ format.
#[derive(Clone, Debug, PartialEq)]
pub struct MetaEntry {
    pub id: u64,
    pub label: u16,
    pub path: String,
}

pub const META_FILE: &str = "metadata.tsv";

/// Serialize metadata as a sequential text file: `id \t label \t path`.
pub fn write_metadata(entries: &[MetaEntry]) -> String {
    let mut s = String::with_capacity(entries.len() * 32);
    for e in entries {
        s.push_str(&format!("{}\t{}\t{}\n", e.id, e.label, e.path));
    }
    s
}

pub fn parse_metadata(text: &str) -> Result<Vec<MetaEntry>> {
    let mut out = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        if line.is_empty() {
            continue;
        }
        let mut it = line.split('\t');
        let (Some(id), Some(label), Some(path)) = (it.next(), it.next(), it.next()) else {
            bail!("metadata line {ln} malformed: {line:?}");
        };
        out.push(MetaEntry {
            id: id.parse().with_context(|| format!("line {ln} id"))?,
            label: label.parse().with_context(|| format!("line {ln} label"))?,
            path: path.to_string(),
        });
    }
    Ok(out)
}

/// Synthesize one planar `[C,H,W]` image for `class`: per-class stripe
/// frequency + phase + dominant channel, a smooth gradient, and noise.
pub fn gen_image(rng: &mut Rng, class: u16, c: usize, h: usize, w: usize) -> codec::Image {
    let mut img = codec::Image::new(c, h, w);
    let freq = 1.0 + (class % 4) as f64;
    let phase = (class / 4) as f64 * std::f64::consts::PI / 4.0;
    let hot = (class as usize) % c;
    for ch in 0..c {
        let amp = if ch == hot { 70.0 } else { 25.0 };
        for y in 0..h {
            for x in 0..w {
                let sx = x as f64 / w as f64;
                let sy = y as f64 / h as f64;
                let stripe = (2.0 * std::f64::consts::PI * freq * sx + phase).sin();
                let grad = 30.0 * sy;
                let noise = rng.normal() * 6.0;
                let v = 120.0 + amp * stripe + grad + noise;
                img.data[ch * h * w + y * w + x] = v.clamp(0.0, 255.0) as u8;
            }
        }
    }
    img
}

/// Configuration for synthetic corpus generation.
#[derive(Clone, Debug)]
pub struct GenConfig {
    pub n_images: usize,
    pub classes: u16,
    pub img_hw: usize,
    pub quality: u8,
    pub seed: u64,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig { n_images: 512, classes: 16, img_hw: 64, quality: 85, seed: 1234 }
    }
}

/// Generate the raw-file corpus: one `.mjx` per image + `metadata.tsv`,
/// written into `store` (the paper's offline dataset preparation).
pub fn generate_raw(store: &DirStore, cfg: &GenConfig) -> Result<Vec<MetaEntry>> {
    ensure!(cfg.classes > 0 && cfg.n_images > 0, "empty dataset config");
    let mut rng = Rng::new(cfg.seed);
    let mut entries = Vec::with_capacity(cfg.n_images);
    for id in 0..cfg.n_images as u64 {
        let class = (rng.gen_range(cfg.classes as u64)) as u16;
        let img = gen_image(&mut rng.fork(id), class, 3, cfg.img_hw, cfg.img_hw);
        let bytes = codec::encode(&img, cfg.quality)?;
        let path = format!("img/{id:06}.mjx");
        store.write(&path, &bytes)?;
        entries.push(MetaEntry { id, label: class, path });
    }
    store.write(META_FILE, write_metadata(&entries).as_bytes())?;
    Ok(entries)
}

/// Offline record-file generation (paper Fig. 1 steps ①–③): read raw
/// files, append into `n_shards` sequential record shards + indexes.
/// Returns shard file names.
pub fn build_records(
    raw: &dyn Storage,
    entries: &[MetaEntry],
    out_dir: &Path,
    n_shards: usize,
) -> Result<Vec<String>> {
    ensure!(n_shards > 0, "need at least one shard");
    std::fs::create_dir_all(out_dir)?;
    let mut writers = Vec::with_capacity(n_shards);
    let mut names = Vec::with_capacity(n_shards);
    for s in 0..n_shards {
        let name = format!("shard-{s:05}.rec");
        writers.push(ShardWriter::create(&out_dir.join(&name))?);
        names.push(name);
    }
    // Contiguous split keeps within-shard ids sequential (better locality).
    let per = entries.len().div_ceil(n_shards);
    for (i, e) in entries.iter().enumerate() {
        let payload = raw.read(&e.path)?;
        writers[i / per].append(e.id, e.label, &payload)?;
    }
    for w in writers {
        w.finish()?;
    }
    Ok(names)
}

/// Epoch sampler (paper steps ❷–❸): split the id list into sequences,
/// shuffle sequence order and contents — "partition the whole file
/// identifier list into a set of smaller sequences and shuffle them".
pub struct EpochSampler {
    ids: Vec<u64>,
    seq_len: usize,
    seed: u64,
}

impl EpochSampler {
    pub fn new(ids: Vec<u64>, seq_len: usize, seed: u64) -> Self {
        EpochSampler { ids, seq_len: seq_len.max(1), seed }
    }

    /// The shuffled id order for `epoch` (deterministic per (seed, epoch)).
    pub fn epoch_order(&self, epoch: u64) -> Vec<u64> {
        let mut rng = Rng::new(self.seed).fork(epoch);
        let mut seqs: Vec<Vec<u64>> =
            self.ids.chunks(self.seq_len).map(|c| c.to_vec()).collect();
        rng.shuffle(&mut seqs);
        for s in seqs.iter_mut() {
            rng.shuffle(s);
        }
        seqs.into_iter().flatten().collect()
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::record::idx_path_for;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("dpp-ds-{name}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn metadata_roundtrip() {
        let entries = vec![
            MetaEntry { id: 0, label: 3, path: "img/000000.mjx".into() },
            MetaEntry { id: 1, label: 15, path: "img/000001.mjx".into() },
        ];
        let text = write_metadata(&entries);
        assert_eq!(parse_metadata(&text).unwrap(), entries);
        assert!(parse_metadata("junk line").is_err());
    }

    #[test]
    fn generated_corpus_is_decodable_and_labeled() {
        let dir = tmp("gen");
        let store = DirStore::new(&dir).unwrap();
        let cfg = GenConfig { n_images: 12, ..Default::default() };
        let entries = generate_raw(&store, &cfg).unwrap();
        assert_eq!(entries.len(), 12);
        for e in &entries {
            assert!(e.label < cfg.classes);
            let img = codec::decode_cpu(&store.read(&e.path).unwrap()).unwrap();
            assert_eq!((img.c, img.h, img.w), (3, 64, 64));
        }
        // Metadata file parses back to the same entries.
        let meta = parse_metadata(
            std::str::from_utf8(&store.read(META_FILE).unwrap()).unwrap(),
        )
        .unwrap();
        assert_eq!(meta, entries);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn images_of_same_class_correlate() {
        // The class signal must be stronger within class than across.
        let a1 = gen_image(&mut Rng::new(1), 2, 3, 64, 64);
        let a2 = gen_image(&mut Rng::new(2), 2, 3, 64, 64);
        let b = gen_image(&mut Rng::new(3), 9, 3, 64, 64);
        let dist = |x: &codec::Image, y: &codec::Image| {
            x.data
                .iter()
                .zip(&y.data)
                .map(|(&p, &q)| ((p as f64) - (q as f64)).powi(2))
                .sum::<f64>()
        };
        assert!(dist(&a1, &a2) < dist(&a1, &b));
    }

    #[test]
    fn record_build_covers_all_images() {
        let dir = tmp("rec");
        let store = DirStore::new(&dir).unwrap();
        let cfg = GenConfig { n_images: 20, img_hw: 16, ..Default::default() };
        let entries = generate_raw(&store, &cfg).unwrap();
        let rec_dir = dir.join("records");
        let shards = build_records(&store, &entries, &rec_dir, 3).unwrap();
        assert_eq!(shards.len(), 3);
        let mut seen = 0;
        for s in &shards {
            let buf = std::fs::read(rec_dir.join(s)).unwrap();
            let recs = crate::record::parse_shard(&buf).unwrap();
            for r in &recs {
                let want = store.read(&entries[r.id as usize].path).unwrap();
                assert_eq!(r.payload[..], want[..]);
                assert_eq!(r.label, entries[r.id as usize].label);
            }
            seen += recs.len();
            assert!(rec_dir.join(idx_path_for(Path::new(s))).exists());
        }
        assert_eq!(seen, 20);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn epoch_order_is_permutation_and_varies() {
        let s = EpochSampler::new((0..100).collect(), 16, 7);
        let e0 = s.epoch_order(0);
        let e1 = s.epoch_order(1);
        let mut sorted = e0.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(e0, e1);
        assert_eq!(e0, s.epoch_order(0), "epoch order not deterministic");
    }
}
