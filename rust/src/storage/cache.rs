//! DRAM cache over any storage backend — the paper's related work
//! (Yang & Cong HiPC'19 distributed cache; OneAccess) built as a
//! first-class feature: epoch N+1 hits memory instead of the device.
//!
//! Byte-budgeted LRU with sharded admission (whole-object caching; record
//! chunks are ranged reads and are cached per (name, offset, len) key —
//! the access pattern is identical across epochs, so ranged keys hit).

use super::Storage;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

#[derive(Clone, Debug, PartialEq, Eq, Hash)]
enum Key {
    Whole(String),
    Range(String, u64, u64),
}

struct Lru {
    map: HashMap<Key, (Vec<u8>, u64)>, // value + last-use tick
    bytes: usize,
    tick: u64,
}

/// Byte-budgeted LRU cache wrapper.
pub struct CachedStore<S: Storage> {
    inner: S,
    budget: usize,
    lru: Mutex<Lru>,
    pub hits: AtomicU64,
    pub misses: AtomicU64,
}

impl<S: Storage> CachedStore<S> {
    pub fn new(inner: S, budget_bytes: usize) -> Self {
        CachedStore {
            inner,
            budget: budget_bytes,
            lru: Mutex::new(Lru { map: HashMap::new(), bytes: 0, tick: 0 }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    pub fn hit_rate(&self) -> f64 {
        let h = self.hits.load(Ordering::Relaxed) as f64;
        let m = self.misses.load(Ordering::Relaxed) as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }

    pub fn cached_bytes(&self) -> usize {
        self.lru.lock().unwrap().bytes
    }

    fn get(&self, key: &Key) -> Option<Vec<u8>> {
        let mut lru = self.lru.lock().unwrap();
        lru.tick += 1;
        let tick = lru.tick;
        if let Some((v, used)) = lru.map.get_mut(key) {
            *used = tick;
            let out = v.clone();
            drop(lru);
            self.hits.fetch_add(1, Ordering::Relaxed);
            Some(out)
        } else {
            drop(lru);
            self.misses.fetch_add(1, Ordering::Relaxed);
            None
        }
    }

    fn admit(&self, key: Key, value: &[u8]) {
        if value.len() > self.budget {
            return; // larger than the whole cache: never admit
        }
        let mut lru = self.lru.lock().unwrap();
        lru.tick += 1;
        let tick = lru.tick;
        // Evict least-recently-used entries until the value fits.
        while lru.bytes + value.len() > self.budget {
            let Some(victim) = lru.map.iter().min_by_key(|(_, (_, used))| *used).map(|(k, _)| k.clone())
            else {
                break;
            };
            if let Some((v, _)) = lru.map.remove(&victim) {
                lru.bytes -= v.len();
            }
        }
        if lru.map.insert(key, (value.to_vec(), tick)).is_none() {
            lru.bytes += value.len();
        }
    }
}

impl<S: Storage> Storage for CachedStore<S> {
    fn read(&self, name: &str) -> Result<Vec<u8>> {
        let key = Key::Whole(name.to_string());
        if let Some(v) = self.get(&key) {
            return Ok(v);
        }
        let v = self.inner.read(name)?;
        self.admit(key, &v);
        Ok(v)
    }

    fn read_range(&self, name: &str, offset: u64, len: u64) -> Result<Vec<u8>> {
        let key = Key::Range(name.to_string(), offset, len);
        if let Some(v) = self.get(&key) {
            return Ok(v);
        }
        let v = self.inner.read_range(name, offset, len)?;
        // A truncated read (range past EOF) must not be cached under the
        // requested (name, offset, len) key: the entry would alias a
        // different range than it holds.  Short reads bypass admission.
        if v.len() as u64 == len {
            self.admit(key, &v);
        }
        Ok(v)
    }

    fn len(&self, name: &str) -> Result<u64> {
        self.inner.len(name)
    }

    fn list(&self) -> Result<Vec<String>> {
        self.inner.list()
    }

    fn stats(&self) -> (u64, u64) {
        self.inner.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::MemStore;

    fn store_with(names: &[(&str, usize)]) -> MemStore {
        let m = MemStore::new();
        for (n, len) in names {
            m.write(n, vec![7u8; *len]);
        }
        m
    }

    #[test]
    fn second_read_hits() {
        let c = CachedStore::new(store_with(&[("a", 100)]), 1 << 20);
        c.read("a").unwrap();
        c.read("a").unwrap();
        assert_eq!(c.hits.load(Ordering::Relaxed), 1);
        assert_eq!(c.misses.load(Ordering::Relaxed), 1);
        // Inner store saw exactly one read.
        assert_eq!(c.stats().1, 1);
    }

    #[test]
    fn lru_evicts_oldest() {
        let c = CachedStore::new(store_with(&[("a", 60), ("b", 60), ("c", 60)]), 128);
        c.read("a").unwrap();
        c.read("b").unwrap(); // a+b = 120 <= 128
        c.read("a").unwrap(); // refresh a
        c.read("c").unwrap(); // evicts b (LRU)
        assert!(c.get(&Key::Whole("a".into())).is_some());
        assert!(c.get(&Key::Whole("b".into())).is_none());
        assert!(c.cached_bytes() <= 128);
    }

    #[test]
    fn oversized_objects_bypass() {
        let c = CachedStore::new(store_with(&[("big", 1000)]), 100);
        c.read("big").unwrap();
        c.read("big").unwrap();
        assert_eq!(c.hits.load(Ordering::Relaxed), 0);
        assert_eq!(c.cached_bytes(), 0);
    }

    #[test]
    fn ranged_reads_cache_by_range() {
        let c = CachedStore::new(store_with(&[("s", 1000)]), 1 << 20);
        c.read_range("s", 0, 100).unwrap();
        c.read_range("s", 100, 100).unwrap();
        c.read_range("s", 0, 100).unwrap(); // hit
        assert_eq!(c.hits.load(Ordering::Relaxed), 1);
        assert_eq!(c.misses.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn truncated_tail_reads_bypass_cache() {
        let c = CachedStore::new(store_with(&[("s", 100)]), 1 << 20);
        // Range runs past EOF: 20 of 50 requested bytes exist.
        assert_eq!(c.read_range("s", 80, 50).unwrap().len(), 20);
        assert_eq!(c.cached_bytes(), 0, "short read must not be admitted");
        // The repeat is correct but never served from a short cache entry.
        assert_eq!(c.read_range("s", 80, 50).unwrap().len(), 20);
        assert_eq!(c.hits.load(Ordering::Relaxed), 0);
        // Exact-length ranges still cache normally.
        assert_eq!(c.read_range("s", 80, 20).unwrap().len(), 20);
        assert_eq!(c.read_range("s", 80, 20).unwrap().len(), 20);
        assert_eq!(c.hits.load(Ordering::Relaxed), 1);
        assert_eq!(c.cached_bytes(), 20);
    }

    #[test]
    fn epoch_pattern_hit_rate() {
        // Two "epochs" over 10 files that all fit: epoch 2 is all hits.
        let names: Vec<String> = (0..10).map(|i| format!("f{i}")).collect();
        let m = MemStore::new();
        for n in &names {
            m.write(n, vec![1u8; 50]);
        }
        let c = CachedStore::new(m, 1 << 20);
        for _ in 0..2 {
            for n in &names {
                c.read(n).unwrap();
            }
        }
        assert!((c.hit_rate() - 0.5).abs() < 1e-9);
    }
}
