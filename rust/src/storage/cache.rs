//! DRAM cache over any storage backend — the paper's related work
//! (Yang & Cong HiPC'19 distributed cache; OneAccess) built as a
//! first-class feature: epoch N+1 hits memory instead of the device.
//!
//! Byte-budgeted LRU with sharded admission (whole-object caching; record
//! chunks are ranged reads and are cached per (name, offset, len) key —
//! the access pattern is identical across epochs, so ranged keys hit).
//!
//! Internals: values are `Arc<[u8]>` so a hit is a refcount bump, not a
//! buffer copy, and the replacement-credit accounting + tick-ordered
//! O(log n) eviction live in the shared [`ByteLru`] core (also used by
//! `pipeline/prep_cache.rs`'s lru arm).

use super::Storage;
use crate::util::bytelru::ByteLru;
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{Arc, Mutex};
use anyhow::Result;

#[derive(Clone, Debug, PartialEq, Eq, Hash)]
enum Key {
    Whole(String),
    Range(String, u64, u64),
}

/// Byte-budgeted LRU cache wrapper.
pub struct CachedStore<S: Storage> {
    inner: S,
    lru: Mutex<ByteLru<Key, Arc<[u8]>>>,
    pub hits: AtomicU64,
    pub misses: AtomicU64,
}

impl<S: Storage> CachedStore<S> {
    pub fn new(inner: S, budget_bytes: usize) -> Self {
        CachedStore {
            inner,
            lru: Mutex::new(ByteLru::new(budget_bytes)),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    pub fn hit_rate(&self) -> f64 {
        // ordering: Relaxed — approximate ratio read of telemetry
        // counters; see `get`.
        let h = self.hits.load(Ordering::Relaxed) as f64;
        let m = self.misses.load(Ordering::Relaxed) as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }

    pub fn cached_bytes(&self) -> usize {
        // poison: only ByteLru map/accounting ops run under this lock
        // (here and in every holder below) — no user code can panic.
        self.lru.lock().unwrap().bytes()
    }

    /// Recompute resident bytes from the entries themselves.  The
    /// accounting invariant (`cached_bytes == recount <= budget`) is what
    /// the property test below drives; a drift means the charged sizes
    /// went stale against the values they account for.
    #[cfg(test)]
    fn recount_bytes(&self) -> usize {
        // poison: see `cached_bytes`.
        self.lru.lock().unwrap().iter().map(|(_, v)| v.len()).sum()
    }

    fn get(&self, key: &Key) -> Option<Arc<[u8]>> {
        // poison: see `cached_bytes`.  refcount bump on the hit.
        let out = self.lru.lock().unwrap().get(key).cloned();
        // ordering: Relaxed — hit/miss telemetry: exact under atomic
        // RMW, consumed as a ratio; the cached bytes themselves are
        // published by the lru mutex, never by these counters.
        match &out {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        out
    }

    fn admit(&self, key: Key, value: Arc<[u8]>) {
        // Replacement credit, eviction, and the oversized-value bypass
        // are the shared core's contract (see util/bytelru.rs).
        let size = value.len();
        // poison: see `cached_bytes`.
        self.lru.lock().unwrap().insert(key, value, size);
    }
}

impl<S: Storage> Storage for CachedStore<S> {
    fn read(&self, name: &str) -> Result<Arc<[u8]>> {
        let key = Key::Whole(name.to_string());
        if let Some(v) = self.get(&key) {
            return Ok(v);
        }
        let v = self.inner.read(name)?;
        self.admit(key, v.clone());
        Ok(v)
    }

    fn read_range(&self, name: &str, offset: u64, len: u64) -> Result<Arc<[u8]>> {
        let key = Key::Range(name.to_string(), offset, len);
        if let Some(v) = self.get(&key) {
            return Ok(v);
        }
        let v = self.inner.read_range(name, offset, len)?;
        // A truncated read (range past EOF) must not be cached under the
        // requested (name, offset, len) key: the entry would alias a
        // different range than it holds.  Short reads bypass admission.
        if v.len() as u64 == len {
            self.admit(key, v.clone());
        }
        Ok(v)
    }

    fn len(&self, name: &str) -> Result<u64> {
        self.inner.len(name)
    }

    fn list(&self) -> Result<Vec<String>> {
        self.inner.list()
    }

    fn stats(&self) -> (u64, u64) {
        self.inner.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::MemStore;
    use crate::testing::{check, PropConfig};

    fn store_with(names: &[(&str, usize)]) -> MemStore {
        let m = MemStore::new();
        for (n, len) in names {
            m.write(*n, vec![7u8; *len]);
        }
        m
    }

    #[test]
    fn second_read_hits() {
        let c = CachedStore::new(store_with(&[("a", 100)]), 1 << 20);
        c.read("a").unwrap();
        c.read("a").unwrap();
        assert_eq!(c.hits.load(Ordering::Relaxed), 1);
        assert_eq!(c.misses.load(Ordering::Relaxed), 1);
        // Inner store saw exactly one read.
        assert_eq!(c.stats().1, 1);
    }

    #[test]
    fn lru_evicts_oldest() {
        let c = CachedStore::new(store_with(&[("a", 60), ("b", 60), ("c", 60)]), 128);
        c.read("a").unwrap();
        c.read("b").unwrap(); // a+b = 120 <= 128
        c.read("a").unwrap(); // refresh a
        c.read("c").unwrap(); // evicts b (LRU)
        assert!(c.get(&Key::Whole("a".into())).is_some());
        assert!(c.get(&Key::Whole("b".into())).is_none());
        assert!(c.cached_bytes() <= 128);
    }

    #[test]
    fn oversized_objects_bypass() {
        let c = CachedStore::new(store_with(&[("big", 1000)]), 100);
        c.read("big").unwrap();
        c.read("big").unwrap();
        assert_eq!(c.hits.load(Ordering::Relaxed), 0);
        assert_eq!(c.cached_bytes(), 0);
    }

    #[test]
    fn ranged_reads_cache_by_range() {
        let c = CachedStore::new(store_with(&[("s", 1000)]), 1 << 20);
        c.read_range("s", 0, 100).unwrap();
        c.read_range("s", 100, 100).unwrap();
        c.read_range("s", 0, 100).unwrap(); // hit
        assert_eq!(c.hits.load(Ordering::Relaxed), 1);
        assert_eq!(c.misses.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn truncated_tail_reads_bypass_cache() {
        let c = CachedStore::new(store_with(&[("s", 100)]), 1 << 20);
        // Range runs past EOF: 20 of 50 requested bytes exist.
        assert_eq!(c.read_range("s", 80, 50).unwrap().len(), 20);
        assert_eq!(c.cached_bytes(), 0, "short read must not be admitted");
        // The repeat is correct but never served from a short cache entry.
        assert_eq!(c.read_range("s", 80, 50).unwrap().len(), 20);
        assert_eq!(c.hits.load(Ordering::Relaxed), 0);
        // Exact-length ranges still cache normally.
        assert_eq!(c.read_range("s", 80, 20).unwrap().len(), 20);
        assert_eq!(c.read_range("s", 80, 20).unwrap().len(), 20);
        assert_eq!(c.hits.load(Ordering::Relaxed), 1);
        assert_eq!(c.cached_bytes(), 20);
    }

    #[test]
    fn epoch_pattern_hit_rate() {
        // Two "epochs" over 10 files that all fit: epoch 2 is all hits.
        let names: Vec<String> = (0..10).map(|i| format!("f{i}")).collect();
        let m = MemStore::new();
        for n in &names {
            m.write(n, vec![1u8; 50]);
        }
        let c = CachedStore::new(m, 1 << 20);
        for _ in 0..2 {
            for n in &names {
                c.read(n).unwrap();
            }
        }
        assert!((c.hit_rate() - 0.5).abs() < 1e-9);
    }

    /// Regression (accounting bugfix): re-admitting an existing key with
    /// a different length — the concurrent double-miss shape — must
    /// credit the replaced entry, not leave `bytes` at the stale sum.
    #[test]
    fn replacing_admission_adjusts_byte_accounting() {
        let c = CachedStore::new(store_with(&[("a", 60)]), 1 << 10);
        let key = Key::Whole("a".into());
        c.admit(key.clone(), vec![1u8; 60].into());
        assert_eq!(c.cached_bytes(), 60);
        c.admit(key.clone(), vec![2u8; 20].into());
        assert_eq!(c.cached_bytes(), 20, "replacement must credit the old entry");
        assert_eq!(c.get(&key).unwrap().len(), 20);
        c.admit(key.clone(), vec![3u8; 90].into());
        assert_eq!(c.cached_bytes(), 90);
        assert_eq!(c.recount_bytes(), 90);
    }

    /// Regression (over-eviction half of the bugfix): replacing a key
    /// only needs room for the size *delta*, so a cache that is exactly
    /// full keeps its other entries when a resident key is re-admitted
    /// at the same length.
    #[test]
    fn replacement_does_not_over_evict() {
        let c = CachedStore::new(store_with(&[("a", 60), ("b", 60)]), 120);
        c.read("a").unwrap();
        c.read("b").unwrap(); // full: 120/120
        c.admit(Key::Whole("a".into()), vec![9u8; 60].into());
        assert!(c.get(&Key::Whole("b".into())).is_some(), "b was needlessly evicted");
        assert_eq!(c.cached_bytes(), 120);
    }

    /// The harness that would have caught the accounting bug: a seeded
    /// random read/read_range workload (run from several threads so
    /// same-key misses race to admit, through an inner store whose
    /// whole-object lengths vary per call) with the invariant
    /// `cached_bytes == Σ resident entry lengths <= budget` checked after
    /// every round.
    #[test]
    fn prop_byte_accounting_is_exact_under_random_workloads() {
        use std::sync::atomic::AtomicU64 as Calls;

        /// MemStore whose whole-object reads come back truncated by a
        /// per-call amount — the deterministic stand-in for "the object
        /// changed size between two racing misses".
        struct VaryStore {
            inner: MemStore,
            calls: Calls,
        }

        impl Storage for VaryStore {
            fn read(&self, name: &str) -> Result<Arc<[u8]>> {
                let v = self.inner.read(name)?;
                let cut = (self.calls.fetch_add(1, Ordering::Relaxed) % 7) as usize;
                Ok(v[..v.len().saturating_sub(cut)].into())
            }
            fn read_range(&self, name: &str, offset: u64, len: u64) -> Result<Arc<[u8]>> {
                self.inner.read_range(name, offset, len)
            }
            fn len(&self, name: &str) -> Result<u64> {
                self.inner.len(name)
            }
            fn list(&self) -> Result<Vec<String>> {
                self.inner.list()
            }
            fn stats(&self) -> (u64, u64) {
                self.inner.stats()
            }
        }

        check(
            "cache-byte-accounting",
            PropConfig { cases: 24, ..Default::default() },
            |rng, size| {
                let budget = 64 + rng.gen_range(64 * size as u64 + 1) as usize;
                let n_blobs = 1 + rng.gen_range(8) as usize;
                let blob_lens: Vec<usize> =
                    (0..n_blobs).map(|_| 8 + rng.gen_range(200) as usize).collect();
                let ops: Vec<(usize, bool, u64, u64)> = (0..40 + 4 * size)
                    .map(|_| {
                        (
                            rng.gen_range(n_blobs as u64) as usize,
                            rng.bool(), // whole vs ranged
                            rng.gen_range(64),
                            1 + rng.gen_range(64),
                        )
                    })
                    .collect();
                (budget, blob_lens, ops)
            },
            |(budget, blob_lens, ops)| {
                let inner = MemStore::new();
                for (i, len) in blob_lens.iter().enumerate() {
                    inner.write(&format!("b{i}"), vec![i as u8; *len]);
                }
                let cache = Arc::new(CachedStore::new(
                    VaryStore { inner, calls: Calls::new(0) },
                    *budget,
                ));
                // Three threads share the op list round-robin so misses on
                // the same key can race to admit.
                let hs: Vec<_> = (0..3)
                    .map(|t| {
                        let cache = cache.clone();
                        let ops = ops.clone();
                        std::thread::spawn(move || {
                            for (blob, whole, off, len) in ops.into_iter().skip(t).step_by(3) {
                                let name = format!("b{blob}");
                                if whole {
                                    cache.read(&name).unwrap();
                                } else {
                                    cache.read_range(&name, off, len).unwrap();
                                }
                            }
                        })
                    })
                    .collect();
                for h in hs {
                    h.join().unwrap();
                }
                cache.cached_bytes() == cache.recount_bytes()
                    && cache.cached_bytes() <= *budget
            },
        );
    }
}
