//! Storage backends + device emulation (paper §4, Fig. 6).
//!
//! The paper compares EBS, instance NVMe SSDs, and DRAM as training-data
//! hosts.  We model a storage *device* as (sequential bandwidth, random
//! IOPS ceiling, per-op latency) and throttle real reads to the profile
//! with a token-bucket.  The same profiles drive both the real engine
//! (sleep-based throttling here) and the discrete-event simulator
//! (analytic service times in `sim/`).
//!
//! Remote object-store tiers (`s3`/`s3-cold`) live in `remote`, modeled
//! as a network path (latency/connections) rather than a device, with the
//! parallel range-GET prefetcher in `prefetch` hiding their latency.

pub mod cache;
pub mod faults;
pub mod prefetch;
pub mod remote;
pub mod retry;

pub use cache::CachedStore;
pub use faults::{FaultProfile, FaultyStore};
pub use prefetch::{fetch_parallel, PrefetchPlan, PrefetchReader};
pub use remote::{NetProfile, RemoteStore};
pub use retry::{RetryPolicy, RetryStats};

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::fs::File;
use std::io::Read;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A storage device profile.  Numbers for EBS/NVMe follow the paper's
/// setup (§3.1, §4: EBS "up to 7500 IOPS", "EBS ... offers similar I/O
/// bandwidths as the attached NVMe SSDs"); DRAM is memory-speed.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StorageProfile {
    pub name: &'static str,
    /// Sequential bandwidth, bytes/s.
    pub seq_bw: f64,
    /// Random-read operations/s ceiling.
    pub rand_iops: f64,
    /// Fixed per-operation latency, seconds.
    pub latency: f64,
}

impl StorageProfile {
    pub const fn ebs() -> Self {
        StorageProfile { name: "ebs", seq_bw: 480e6, rand_iops: 7_500.0, latency: 500e-6 }
    }

    pub const fn nvme() -> Self {
        StorageProfile { name: "nvme", seq_bw: 500e6, rand_iops: 200_000.0, latency: 80e-6 }
    }

    pub const fn dram() -> Self {
        StorageProfile { name: "dram", seq_bw: 60e9, rand_iops: 50_000_000.0, latency: 0.2e-6 }
    }

    /// Every built-in local tier name (kept in sync with `by_name`;
    /// `config::RunConfig` validation tests assert the parity).
    pub fn names() -> &'static [&'static str] {
        &["ebs", "nvme", "dram"]
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "ebs" => Some(Self::ebs()),
            "nvme" => Some(Self::nvme()),
            "dram" => Some(Self::dram()),
            _ => None,
        }
    }

    /// Analytic service time for a read of `len` bytes (used by `sim/`):
    /// sequential = latency + transfer; random additionally pays the
    /// IOPS token (seek/queue cost), which is what makes raw-file loading
    /// slower than record streaming on disk-backed stores (paper §3.2).
    pub fn service_time(&self, len: u64, sequential: bool) -> f64 {
        let xfer = len as f64 / self.seq_bw;
        let iop = if sequential { 0.0 } else { 1.0 / self.rand_iops };
        self.latency + iop + xfer
    }
}

/// Byte-level statistics every store keeps (feeds the Fig. 4 I/O trace).
#[derive(Debug, Default)]
pub struct IoStats {
    pub bytes_read: AtomicU64,
    pub reads: AtomicU64,
}

impl IoStats {
    pub fn record(&self, bytes: u64) {
        // ordering: Relaxed — monotonic I/O telemetry; exact under
        // atomic RMW, consumed as approximate rates (Fig. 4 trace) or
        // read after the pipeline joins.  The data read is published by
        // the store's own return path, never by these counters.
        self.bytes_read.fetch_add(bytes, Ordering::Relaxed);
        self.reads.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> (u64, u64) {
        // ordering: Relaxed — approximate paired read; the two fields
        // need no mutual consistency (rates tolerate a one-op skew).
        (self.bytes_read.load(Ordering::Relaxed), self.reads.load(Ordering::Relaxed))
    }
}

/// Object-store style interface over named blobs.  `read_range` is the
/// random-access path (raw files / indexed records); `read` fetches a
/// whole object (record chunks use ranged reads).
///
/// Reads return `Arc<[u8]>` so memory-resident tiers (`MemStore`, the
/// caches) serve repeat reads as refcount bumps instead of buffer copies.
pub trait Storage: Send + Sync {
    fn read(&self, name: &str) -> Result<Arc<[u8]>>;
    fn read_range(&self, name: &str, offset: u64, len: u64) -> Result<Arc<[u8]>>;
    fn len(&self, name: &str) -> Result<u64>;
    fn list(&self) -> Result<Vec<String>>;
    fn stats(&self) -> (u64, u64);
}

/// Forwarding impl so cache/throttle wrappers can stack over trait objects.
impl<S: Storage + ?Sized> Storage for std::sync::Arc<S> {
    fn read(&self, name: &str) -> Result<Arc<[u8]>> {
        (**self).read(name)
    }

    fn read_range(&self, name: &str, offset: u64, len: u64) -> Result<Arc<[u8]>> {
        (**self).read_range(name, offset, len)
    }

    fn len(&self, name: &str) -> Result<u64> {
        (**self).len(name)
    }

    fn list(&self) -> Result<Vec<String>> {
        (**self).list()
    }

    fn stats(&self) -> (u64, u64) {
        (**self).stats()
    }
}

// ---------------------------------------------------------------------------
// DirStore: real files in a directory
// ---------------------------------------------------------------------------

/// Blob name of `path` relative to `root`.  A walked entry that does not
/// live under the root (symlink escape, mount-point oddity, a `..`
/// component the OS resolved differently than the lexical prefix) is a
/// hard error naming the offending path — it used to be an `unwrap`
/// panic deep inside `list`, which aborted the whole process instead of
/// surfacing a diagnosable storage error.
fn rel_name(root: &Path, path: &Path) -> Result<String> {
    let rel = path.strip_prefix(root).map_err(|_| {
        anyhow::anyhow!("walked entry {path:?} is not under storage root {root:?}")
    })?;
    Ok(rel.to_string_lossy().into_owned())
}

pub struct DirStore {
    root: PathBuf,
    stats: IoStats,
}

impl DirStore {
    pub fn new(root: &Path) -> Result<Self> {
        std::fs::create_dir_all(root).with_context(|| format!("mkdir {root:?}"))?;
        Ok(DirStore { root: root.to_path_buf(), stats: IoStats::default() })
    }

    pub fn write(&self, name: &str, bytes: &[u8]) -> Result<()> {
        let p = self.root.join(name);
        if let Some(parent) = p.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(&p, bytes).with_context(|| format!("write {p:?}"))
    }

    pub fn root(&self) -> &Path {
        &self.root
    }
}

impl Storage for DirStore {
    fn read(&self, name: &str) -> Result<Arc<[u8]>> {
        let p = self.root.join(name);
        let b = std::fs::read(&p).with_context(|| format!("read {p:?}"))?;
        self.stats.record(b.len() as u64);
        Ok(b.into())
    }

    fn read_range(&self, name: &str, offset: u64, len: u64) -> Result<Arc<[u8]>> {
        use std::io::Seek;
        let p = self.root.join(name);
        let mut f = File::open(&p).with_context(|| format!("open {p:?}"))?;
        f.seek(std::io::SeekFrom::Start(offset))?;
        let mut buf = vec![0u8; len as usize];
        let mut read = 0;
        while read < buf.len() {
            let n = f.read(&mut buf[read..])?;
            if n == 0 {
                break;
            }
            read += n;
        }
        buf.truncate(read);
        self.stats.record(read as u64);
        Ok(buf.into())
    }

    fn len(&self, name: &str) -> Result<u64> {
        Ok(std::fs::metadata(self.root.join(name))?.len())
    }

    fn list(&self) -> Result<Vec<String>> {
        // Recursive walk, names relative to the root ("img/000001.mjx").
        fn walk(root: &Path, dir: &Path, out: &mut Vec<String>) -> Result<()> {
            for e in std::fs::read_dir(dir)? {
                let e = e?;
                let ft = e.file_type()?;
                if ft.is_dir() {
                    walk(root, &e.path(), out)?;
                } else if ft.is_file() {
                    out.push(rel_name(root, &e.path())?);
                }
            }
            Ok(())
        }
        let mut names = Vec::new();
        walk(&self.root, &self.root, &mut names)?;
        names.sort();
        Ok(names)
    }

    fn stats(&self) -> (u64, u64) {
        self.stats.snapshot()
    }
}

// ---------------------------------------------------------------------------
// MemStore: DRAM-resident blobs
// ---------------------------------------------------------------------------

#[derive(Default)]
pub struct MemStore {
    blobs: Mutex<HashMap<String, Arc<[u8]>>>,
    stats: IoStats,
}

impl MemStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn write(&self, name: &str, bytes: impl Into<Arc<[u8]>>) {
        // poison: holders only touch the HashMap, which never panics
        // mid-update here; a poisoned map means a crashed thread and the
        // run is already lost — propagating the panic is correct.
        self.blobs.lock().unwrap().insert(name.to_string(), bytes.into());
    }

    /// Preload every blob of another store (the paper's "load data to
    /// DRAM first" configuration).
    pub fn preload_from(src: &dyn Storage) -> Result<Self> {
        let m = MemStore::new();
        for name in src.list()? {
            let data = src.read(&name)?;
            m.write(&name, data);
        }
        Ok(m)
    }
}

impl Storage for MemStore {
    fn read(&self, name: &str) -> Result<Arc<[u8]>> {
        // Whole-object reads are refcount bumps, not copies.
        // poison: see `write` — map ops can't panic under the lock.
        let b = self
            .blobs
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .with_context(|| format!("no blob {name}"))?;
        self.stats.record(b.len() as u64);
        Ok(b)
    }

    fn read_range(&self, name: &str, offset: u64, len: u64) -> Result<Arc<[u8]>> {
        // poison: see `write` — map ops can't panic under the lock.
        let g = self.blobs.lock().unwrap();
        let b = g.get(name).with_context(|| format!("no blob {name}"))?;
        let start = (offset as usize).min(b.len());
        let end = (start + len as usize).min(b.len());
        self.stats.record((end - start) as u64);
        Ok(b[start..end].into())
    }

    fn len(&self, name: &str) -> Result<u64> {
        // poison: see `write` — map ops can't panic under the lock.
        let g = self.blobs.lock().unwrap();
        Ok(g.get(name).with_context(|| format!("no blob {name}"))?.len() as u64)
    }

    fn list(&self) -> Result<Vec<String>> {
        // poison: see `write` — map ops can't panic under the lock.
        let mut names: Vec<String> = self.blobs.lock().unwrap().keys().cloned().collect();
        names.sort();
        Ok(names)
    }

    fn stats(&self) -> (u64, u64) {
        self.stats.snapshot()
    }
}

// ---------------------------------------------------------------------------
// ThrottledStore: token-bucket device emulation over any inner store
// ---------------------------------------------------------------------------

struct Bucket {
    /// Time at which the device becomes free (monotonic seconds from t0).
    busy_until: f64,
}

pub struct ThrottledStore<S: Storage> {
    inner: S,
    profile: StorageProfile,
    t0: Instant,
    bucket: Mutex<Bucket>,
    /// Scale factor on emulated delays (1.0 = real-time emulation;
    /// smaller speeds tests up while keeping relative costs).
    time_scale: f64,
}

impl<S: Storage> ThrottledStore<S> {
    pub fn new(inner: S, profile: StorageProfile) -> Self {
        Self::with_time_scale(inner, profile, 1.0)
    }

    pub fn with_time_scale(inner: S, profile: StorageProfile, time_scale: f64) -> Self {
        ThrottledStore {
            inner,
            profile,
            t0: Instant::now(),
            bucket: Mutex::new(Bucket { busy_until: 0.0 }),
            time_scale,
        }
    }

    pub fn profile(&self) -> StorageProfile {
        self.profile
    }

    fn throttle(&self, len: u64, sequential: bool) {
        let service = self.profile.service_time(len, sequential) * self.time_scale;
        let now = self.t0.elapsed().as_secs_f64();
        let wake;
        {
            // poison: only f64 arithmetic runs under the lock — no panic
            // source; a poisoned bucket means a crashed reader thread.
            let mut b = self.bucket.lock().unwrap();
            let start = b.busy_until.max(now);
            b.busy_until = start + service;
            wake = b.busy_until;
        }
        let sleep = wake - now;
        if sleep > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(sleep));
        }
    }
}

impl<S: Storage> Storage for ThrottledStore<S> {
    fn read(&self, name: &str) -> Result<Arc<[u8]>> {
        let len = self.inner.len(name)?;
        self.throttle(len, true);
        self.inner.read(name)
    }

    fn read_range(&self, name: &str, offset: u64, len: u64) -> Result<Arc<[u8]>> {
        // Ranged reads are random I/O unless they are large chunks.
        let sequential = len >= 1 << 20;
        self.throttle(len, sequential);
        self.inner.read_range(name, offset, len)
    }

    fn len(&self, name: &str) -> Result<u64> {
        self.inner.len(name)
    }

    fn list(&self) -> Result<Vec<String>> {
        self.inner.list()
    }

    fn stats(&self) -> (u64, u64) {
        self.inner.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_sane() {
        let ebs = StorageProfile::ebs();
        let nvme = StorageProfile::nvme();
        let dram = StorageProfile::dram();
        assert!(dram.seq_bw > nvme.seq_bw && nvme.seq_bw >= ebs.seq_bw * 0.9);
        assert!(nvme.rand_iops > ebs.rand_iops);
        assert_eq!(StorageProfile::by_name("ebs").unwrap().name, "ebs");
        assert!(StorageProfile::by_name("floppy").is_none());
        for name in StorageProfile::names() {
            assert_eq!(StorageProfile::by_name(name).unwrap().name, *name);
        }
    }

    #[test]
    fn service_time_random_vs_sequential() {
        let ebs = StorageProfile::ebs();
        let small = 100_000u64; // 100 KB image
        // Random read of a small object is IOPS-bound on EBS.
        assert!(ebs.service_time(small, false) > ebs.service_time(small, true));
        // Large sequential read is bandwidth-bound.
        let t = ebs.service_time(64 << 20, true);
        assert!((t - (64.0 * (1 << 20) as f64 / 480e6 + 500e-6)).abs() < 1e-6);
        // The IOPS token is exactly the random/sequential gap.
        let gap = ebs.service_time(small, false) - ebs.service_time(small, true);
        assert!((gap - 1.0 / 7500.0).abs() < 1e-9);
    }

    #[test]
    fn memstore_roundtrip_and_range() {
        let m = MemStore::new();
        m.write("a", vec![1u8, 2, 3, 4, 5]);
        assert_eq!(m.read("a").unwrap()[..], [1, 2, 3, 4, 5]);
        assert_eq!(m.read_range("a", 1, 3).unwrap()[..], [2, 3, 4]);
        assert_eq!(m.read_range("a", 3, 100).unwrap()[..], [4, 5]);
        assert_eq!(m.len("a").unwrap(), 5);
        assert!(m.read("b").is_err());
        let (bytes, reads) = m.stats();
        assert_eq!(reads, 3);
        assert_eq!(bytes, 5 + 3 + 2);
    }

    #[test]
    fn dirstore_roundtrip() {
        let dir = std::env::temp_dir().join(format!("dpp-store-{}", std::process::id()));
        let s = DirStore::new(&dir).unwrap();
        s.write("x.bin", &[9u8; 1000]).unwrap();
        s.write("y.bin", &[7u8; 10]).unwrap();
        assert_eq!(s.read("x.bin").unwrap().len(), 1000);
        assert_eq!(s.read_range("x.bin", 990, 100).unwrap().len(), 10);
        assert_eq!(s.list().unwrap(), vec!["x.bin".to_string(), "y.bin".to_string()]);
        std::fs::remove_dir_all(dir).ok();
    }

    /// Regression for the `DirStore::list` panic: an entry outside the
    /// root used to hit `strip_prefix(..).unwrap()` and abort the
    /// process.  The relative-name helper now returns an error that
    /// names the offending path, and stays correct for ordinary
    /// (nested) entries.
    #[test]
    fn rel_name_errors_instead_of_panicking_outside_root() {
        let root = Path::new("/data/corpus");
        assert_eq!(rel_name(root, Path::new("/data/corpus/img/x.mjx")).unwrap(), "img/x.mjx");
        let err = rel_name(root, Path::new("/other/place/x.mjx")).unwrap_err().to_string();
        assert!(err.contains("/other/place/x.mjx"), "must name the offending path: {err}");
        assert!(err.contains("/data/corpus"), "must name the root: {err}");
    }

    #[test]
    fn preload_copies_everything() {
        let dir = std::env::temp_dir().join(format!("dpp-preload-{}", std::process::id()));
        let s = DirStore::new(&dir).unwrap();
        s.write("a", &[1u8; 64]).unwrap();
        s.write("b", &[2u8; 32]).unwrap();
        let m = MemStore::preload_from(&s).unwrap();
        assert_eq!(m.read("a").unwrap()[..], [1u8; 64]);
        assert_eq!(m.read("b").unwrap()[..], [2u8; 32]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn throttled_store_enforces_bandwidth() {
        // 1 MB/s profile, 100 KB read => >= ~90ms.
        let prof = StorageProfile { name: "slow", seq_bw: 1e6, rand_iops: 1e9, latency: 0.0 };
        let m = MemStore::new();
        m.write("a", vec![0u8; 100_000]);
        let t = ThrottledStore::new(m, prof);
        let start = Instant::now();
        t.read("a").unwrap();
        assert!(start.elapsed() >= Duration::from_millis(90));
    }

    #[test]
    fn throttled_store_time_scale_speeds_up() {
        let prof = StorageProfile { name: "slow", seq_bw: 1e6, rand_iops: 1e9, latency: 0.0 };
        let m = MemStore::new();
        m.write("a", vec![0u8; 100_000]);
        let t = ThrottledStore::with_time_scale(m, prof, 0.01);
        let start = Instant::now();
        t.read("a").unwrap();
        assert!(start.elapsed() < Duration::from_millis(50));
    }

    #[test]
    fn throttled_serializes_concurrent_readers() {
        use std::sync::Arc;
        let prof = StorageProfile { name: "slow", seq_bw: 10e6, rand_iops: 1e9, latency: 0.0 };
        let m = MemStore::new();
        m.write("a", vec![0u8; 100_000]); // 10ms each at 10MB/s
        let t = Arc::new(ThrottledStore::new(m, prof));
        let start = Instant::now();
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let t = t.clone();
                std::thread::spawn(move || t.read("a").unwrap())
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        // 4 reads x 10ms serialized through one device >= ~35ms.
        assert!(start.elapsed() >= Duration::from_millis(35));
    }
}
