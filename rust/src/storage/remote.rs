//! Emulated remote object store — the dominant real cloud deployment the
//! local tiers (`ebs`/`nvme`/`dram`) cannot represent: training data in
//! S3/GCS, where *per-request latency* and *connection parallelism*, not
//! device IOPS, bound the loader (Mohan et al., "Analyzing and Mitigating
//! Data Stalls in DNN Training").
//!
//! A [`NetProfile`] models the network path as (per-request first-byte
//! latency, per-connection bandwidth, aggregate bandwidth, connection-pool
//! size, request-rate ceiling).  [`RemoteStore`] enforces it over any inner
//! [`Storage`]:
//!
//! * a connection **semaphore** caps in-flight requests at `max_conns` —
//!   concurrency up to the cap genuinely overlaps latency, which is what
//!   the parallel range-GET prefetcher (`prefetch.rs`) exploits;
//! * a shared **token bucket** serializes the aggregate-bandwidth share of
//!   each transfer (the latency share deliberately does *not* serialize);
//! * a request-rate bucket spaces request admissions at `1/max_rps`.
//!
//! The same profile drives the simulator's analytic service-time model via
//! [`NetProfile::throughput_bps`], so real and simulated remote runs stay
//! comparable (tested to within 20% in `tests/remote_prefetch.rs`).

use super::Storage;
use crate::metrics::Gauge;
use anyhow::Result;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Network path profile for an emulated object store.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NetProfile {
    pub name: &'static str,
    /// Per-request time-to-first-byte, seconds.
    pub latency: f64,
    /// Per-connection bandwidth cap, bytes/s.
    pub conn_bw: f64,
    /// Aggregate bandwidth cap across all connections, bytes/s.
    pub agg_bw: f64,
    /// Maximum concurrent in-flight requests (connection-pool size).
    pub max_conns: usize,
    /// Request-rate throttle, requests/s (0 = unlimited).
    pub max_rps: f64,
}

impl NetProfile {
    /// Warm S3-class store: ~30 ms first byte, ~90 MB/s per connection,
    /// instance-NIC-class aggregate, the 5500 GET/s per-prefix ceiling.
    pub const fn s3() -> Self {
        NetProfile {
            name: "s3",
            latency: 30e-3,
            conn_bw: 90e6,
            agg_bw: 2.0e9,
            max_conns: 64,
            max_rps: 5500.0,
        }
    }

    /// Cold/infrequent-access S3-class store: ~150 ms first byte and a
    /// slower, more contended per-connection path.
    pub const fn s3_cold() -> Self {
        NetProfile {
            name: "s3-cold",
            latency: 150e-3,
            conn_bw: 40e6,
            agg_bw: 1.0e9,
            max_conns: 64,
            max_rps: 2000.0,
        }
    }

    /// Every built-in remote tier name (kept in sync with `by_name`;
    /// `config::RunConfig` validation tests assert the parity).
    pub fn names() -> &'static [&'static str] {
        &["s3", "s3-cold"]
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "s3" => Some(Self::s3()),
            "s3-cold" => Some(Self::s3_cold()),
            _ => None,
        }
    }

    /// Wall-clock of one ranged GET of `len` bytes on one idle connection.
    pub fn request_time(&self, len: u64) -> f64 {
        self.latency + len as f64 / self.conn_bw
    }

    /// Analytic steady-state byte throughput of `conns` connections
    /// streaming parts of `part` bytes each: per-connection pipelining
    /// overlaps latency across connections until the aggregate-bandwidth
    /// or request-rate ceiling binds.  This is the service-time model the
    /// simulator (`sim/`) uses for the remote tiers.
    pub fn throughput_bps(&self, conns: usize, part: u64) -> f64 {
        let conns = conns.max(1).min(self.max_conns.max(1)) as f64;
        let part_f = (part.max(1)) as f64;
        let per_conn = part_f / self.request_time(part.max(1));
        let mut cap = (conns * per_conn).min(self.agg_bw);
        if self.max_rps > 0.0 {
            cap = cap.min(self.max_rps * part_f);
        }
        cap
    }
}

/// Counting semaphore (std has none; no tokio offline).
struct Semaphore {
    free: Mutex<usize>,
    cv: Condvar,
}

impl Semaphore {
    fn new(n: usize) -> Self {
        Semaphore { free: Mutex::new(n.max(1)), cv: Condvar::new() }
    }

    fn acquire(&self) {
        // poison: only the counter +=/-= runs under this lock (here and
        // in `release`) — no panic path.
        let mut free = self.free.lock().unwrap();
        while *free == 0 {
            free = self.cv.wait(free).unwrap();
        }
        *free -= 1;
    }

    fn release(&self) {
        // poison: see `acquire`.
        *self.free.lock().unwrap() += 1;
        self.cv.notify_one();
    }
}

/// Emulated S3-style object store over any inner backend.
///
/// Reads acquire a connection slot, pay the profile's latency + transfer
/// time (sleep-based, like `ThrottledStore`), and release the slot; `len`
/// and `list` are metadata operations and pass through unthrottled (HEAD
/// results are cached by real clients).
pub struct RemoteStore<S: Storage> {
    inner: S,
    profile: NetProfile,
    t0: Instant,
    /// Aggregate-bandwidth bucket: time the shared pipe is busy until
    /// (scaled monotonic seconds from `t0`).
    bw_busy_until: Mutex<f64>,
    /// Request-rate bucket: earliest admissible next request start.
    next_request_at: Mutex<f64>,
    conns: Semaphore,
    /// Scale factor on emulated delays (1.0 = real time; small values
    /// speed tests up while keeping relative costs).
    time_scale: f64,
    /// In-flight request gauge (level + peak) — Fig. 4-style telemetry.
    pub in_flight: Gauge,
}

impl<S: Storage> RemoteStore<S> {
    pub fn new(inner: S, profile: NetProfile) -> Self {
        Self::with_time_scale(inner, profile, 1.0)
    }

    pub fn with_time_scale(inner: S, profile: NetProfile, time_scale: f64) -> Self {
        RemoteStore {
            inner,
            t0: Instant::now(),
            bw_busy_until: Mutex::new(0.0),
            next_request_at: Mutex::new(0.0),
            conns: Semaphore::new(profile.max_conns),
            profile,
            time_scale,
            in_flight: Gauge::new(),
        }
    }

    pub fn profile(&self) -> NetProfile {
        self.profile
    }

    fn now(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    /// Emulate one GET that moved `len` bytes; the caller must already
    /// hold a connection slot.
    fn delay(&self, len: u64) {
        let now = self.now();
        // Request-rate admission: starts are spaced 1/max_rps apart.
        let start = if self.profile.max_rps > 0.0 {
            // poison: float bookkeeping only under both pacing locks
            // (this one and `bw_busy_until` below).
            let mut next = self.next_request_at.lock().unwrap();
            let s = next.max(now);
            *next = s + self.time_scale / self.profile.max_rps;
            s
        } else {
            now
        };
        // The transfer share serializes through the shared pipe; the
        // latency share overlaps across connections (the whole point).
        let xfer_agg = len as f64 / self.profile.agg_bw * self.time_scale;
        let bw_done = {
            // poison: see the pacing note above.
            let mut busy = self.bw_busy_until.lock().unwrap();
            let s = busy.max(start);
            *busy = s + xfer_agg;
            *busy
        };
        let conn_done = start + self.profile.request_time(len) * self.time_scale;
        let sleep = conn_done.max(bw_done) - self.now();
        if sleep > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(sleep));
        }
    }

    fn request<T>(&self, f: impl FnOnce() -> Result<T>, len_of: impl FnOnce(&T) -> u64) -> Result<T> {
        self.conns.acquire();
        self.in_flight.inc();
        let out = f();
        if let Ok(v) = &out {
            self.delay(len_of(v));
        }
        self.in_flight.dec();
        self.conns.release();
        out
    }
}

impl<S: Storage> Storage for RemoteStore<S> {
    fn read(&self, name: &str) -> Result<Arc<[u8]>> {
        self.request(|| self.inner.read(name), |v| v.len() as u64)
    }

    fn read_range(&self, name: &str, offset: u64, len: u64) -> Result<Arc<[u8]>> {
        // Charge the bytes actually moved (short near EOF), not requested.
        self.request(|| self.inner.read_range(name, offset, len), |v| v.len() as u64)
    }

    fn len(&self, name: &str) -> Result<u64> {
        self.inner.len(name)
    }

    fn list(&self) -> Result<Vec<String>> {
        self.inner.list()
    }

    fn stats(&self) -> (u64, u64) {
        self.inner.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::MemStore;
    use std::sync::Arc;

    fn mem_with(name: &str, len: usize) -> MemStore {
        let m = MemStore::new();
        m.write(name, vec![5u8; len]);
        m
    }

    #[test]
    fn profiles_sane_and_lookup_matches_names() {
        let s3 = NetProfile::s3();
        let cold = NetProfile::s3_cold();
        assert!(cold.latency > s3.latency);
        assert!(cold.conn_bw < s3.conn_bw);
        for name in NetProfile::names() {
            assert_eq!(NetProfile::by_name(name).unwrap().name, *name);
        }
        assert!(NetProfile::by_name("ebs").is_none());
        assert!(NetProfile::by_name("floppy").is_none());
    }

    #[test]
    fn throughput_model_scales_with_conns_then_saturates() {
        let p = NetProfile::s3();
        let part = 1 << 20;
        let one = p.throughput_bps(1, part);
        let eight = p.throughput_bps(8, part);
        assert!((eight / one - 8.0).abs() < 1e-6, "latency hiding is linear below the caps");
        // Past the pool size the cap stops growing.
        assert_eq!(p.throughput_bps(p.max_conns, part), p.throughput_bps(p.max_conns * 4, part));
        // Tiny parts are request-rate bound.
        let tiny = p.throughput_bps(64, 1024);
        assert!(tiny <= p.max_rps * 1024.0 + 1e-6, "{tiny}");
    }

    #[test]
    fn single_request_pays_latency_and_transfer() {
        let prof = NetProfile {
            name: "t",
            latency: 40e-3,
            conn_bw: 10e6,
            agg_bw: 1e9,
            max_conns: 8,
            max_rps: 0.0,
        };
        let r = RemoteStore::new(mem_with("a", 100_000), prof);
        let t = Instant::now();
        r.read("a").unwrap();
        // 40 ms latency + 10 ms transfer at 10 MB/s.
        assert!(t.elapsed() >= Duration::from_millis(45), "{:?}", t.elapsed());
    }

    #[test]
    fn concurrent_requests_overlap_latency() {
        // Latency-dominated profile: 8 concurrent reads should take ~1x
        // the latency, not 8x.
        let prof = NetProfile {
            name: "t",
            latency: 30e-3,
            conn_bw: 1e9,
            agg_bw: 8e9,
            max_conns: 8,
            max_rps: 0.0,
        };
        let m = MemStore::new();
        for i in 0..8 {
            m.write(&format!("f{i}"), vec![0u8; 10_000]);
        }
        let r = Arc::new(RemoteStore::new(m, prof));
        let t = Instant::now();
        let hs: Vec<_> = (0..8)
            .map(|i| {
                let r = r.clone();
                std::thread::spawn(move || r.read(&format!("f{i}")).unwrap())
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        let el = t.elapsed();
        assert!(el >= Duration::from_millis(28), "{el:?}");
        // Serialized latency would be ~240 ms; leave scheduling headroom.
        assert!(el < Duration::from_millis(150), "latency did not overlap: {el:?}");
        assert_eq!(r.in_flight.value(), 0);
        assert!(r.in_flight.peak() >= 2, "peak {}", r.in_flight.peak());
    }

    #[test]
    fn max_conns_serializes_excess_requests() {
        let prof = NetProfile {
            name: "t",
            latency: 20e-3,
            conn_bw: 1e9,
            agg_bw: 8e9,
            max_conns: 2,
            max_rps: 0.0,
        };
        let m = MemStore::new();
        for i in 0..8 {
            m.write(&format!("f{i}"), vec![0u8; 1000]);
        }
        let r = Arc::new(RemoteStore::new(m, prof));
        let t = Instant::now();
        let hs: Vec<_> = (0..8)
            .map(|i| {
                let r = r.clone();
                std::thread::spawn(move || r.read(&format!("f{i}")).unwrap())
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        // 8 requests through 2 slots >= 4 waves x 20 ms.
        assert!(t.elapsed() >= Duration::from_millis(70), "{:?}", t.elapsed());
        assert!(r.in_flight.peak() <= 2, "pool leaked: {}", r.in_flight.peak());
    }

    #[test]
    fn aggregate_bandwidth_serializes_transfers() {
        // Transfer-dominated: per-conn bw is huge but the shared pipe is
        // 10 MB/s, so 4x 100 KB concurrent reads still take >= ~35 ms.
        let prof = NetProfile {
            name: "t",
            latency: 0.0,
            conn_bw: 1e12,
            agg_bw: 10e6,
            max_conns: 8,
            max_rps: 0.0,
        };
        let m = MemStore::new();
        for i in 0..4 {
            m.write(&format!("f{i}"), vec![0u8; 100_000]);
        }
        let r = Arc::new(RemoteStore::new(m, prof));
        let t = Instant::now();
        let hs: Vec<_> = (0..4)
            .map(|i| {
                let r = r.clone();
                std::thread::spawn(move || r.read(&format!("f{i}")).unwrap())
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert!(t.elapsed() >= Duration::from_millis(35), "{:?}", t.elapsed());
    }

    #[test]
    fn time_scale_speeds_emulation_up() {
        let r = RemoteStore::with_time_scale(mem_with("a", 1000), NetProfile::s3_cold(), 0.01);
        let t = Instant::now();
        r.read("a").unwrap();
        // 150 ms cold latency scaled by 0.01 => ~1.5 ms (bound leaves
        // scheduling headroom; unscaled would be >= 150 ms).
        assert!(t.elapsed() < Duration::from_millis(100), "{:?}", t.elapsed());
    }

    #[test]
    fn short_tail_range_charged_for_actual_bytes() {
        let prof = NetProfile {
            name: "t",
            latency: 0.0,
            conn_bw: 1e6, // 1 MB/s => 1 ms per KB
            agg_bw: 1e9,
            max_conns: 4,
            max_rps: 0.0,
        };
        let r = RemoteStore::new(mem_with("a", 2_000), prof);
        let t = Instant::now();
        // Request 100 KB at the tail; only 1 KB exists.
        let v = r.read_range("a", 1_000, 100_000).unwrap();
        assert_eq!(v.len(), 1_000);
        assert!(t.elapsed() < Duration::from_millis(50), "charged requested len: {:?}", t.elapsed());
    }
}
