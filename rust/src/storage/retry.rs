//! Retry policy for remote/faulty reads: bounded attempts with
//! exponential backoff + deterministic jitter under a per-request
//! deadline.
//!
//! Object stores fail as a matter of course — transient 5xx, dropped
//! connections, 503 SlowDown throttling — and the standard client cure
//! (what the AWS SDKs and s3bfg-style fetchers do) is to retry with
//! exponential backoff and jitter, giving up only when a per-request
//! time budget is exhausted.  The policy here is deliberately small and
//! *deterministic*: jitter derives from a seed + request key, never from
//! wall-clock entropy, so a failing run replays exactly under the same
//! seed (the property `storage/faults.rs` injection is built around).
//!
//! Two consumers:
//! * [`with_retry`] — inline loop around a blocking read (the runner's
//!   raw-file path).
//! * `storage/prefetch.rs` — re-issues failed parts through its sliding
//!   window instead of looping inline, so a backoff never parks a
//!   connection; it uses [`RetryPolicy::backoff_secs`] and
//!   [`RetryStats`] directly.

use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Bounded-retry policy.  `attempts` counts *total* tries, so `1`
/// disables retrying entirely (the pre-fault-layer behavior).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryPolicy {
    /// Max total attempts per request (1 = no retry).
    pub attempts: u32,
    /// First backoff, seconds; doubles per attempt.
    pub base_backoff: f64,
    /// Backoff ceiling, seconds.
    pub max_backoff: f64,
    /// Per-request wall-clock budget, seconds: once a request has been
    /// failing for this long, stop retrying even with attempts left.
    /// Checked between attempts — a blocking read in flight cannot be
    /// cancelled, so this bounds *queued* retry time, not one read.
    pub deadline: f64,
    /// Jitter seed (mixed with the request key per attempt).
    pub seed: u64,
}

impl RetryPolicy {
    /// No retrying at all: first failure surfaces immediately.
    pub fn none() -> Self {
        RetryPolicy {
            attempts: 1,
            base_backoff: 0.0,
            max_backoff: 0.0,
            deadline: f64::INFINITY,
            seed: 0,
        }
    }

    /// `retries` extra attempts after the first, with the default
    /// backoff/deadline shape (2 ms doubling to 100 ms, 30 s budget).
    pub fn with_retries(retries: u32, deadline: f64, seed: u64) -> Self {
        RetryPolicy {
            attempts: retries + 1,
            base_backoff: 2e-3,
            max_backoff: 0.1,
            deadline,
            seed,
        }
    }

    pub fn enabled(&self) -> bool {
        self.attempts > 1
    }

    /// Backoff before attempt `attempt` (2, 3, ...) of request `key`:
    /// `base * 2^(attempt-2)`, capped, with deterministic jitter in
    /// [0.5, 1.0]x — the decorrelation that keeps a burst of failed
    /// requests from retrying in lockstep, yet replays exactly by seed.
    pub fn backoff_secs(&self, attempt: u32, key: u64) -> f64 {
        if self.base_backoff <= 0.0 {
            return 0.0;
        }
        let exp = attempt.saturating_sub(2).min(16);
        let raw = (self.base_backoff * f64::from(1u32 << exp)).min(self.max_backoff);
        // SplitMix-style mix of (seed, key, attempt) → one jitter draw.
        let salt = key
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(u64::from(attempt).wrapping_mul(0xBF58_476D_1CE4_E5B9));
        let mut rng = Rng::new(self.seed ^ salt);
        raw * (0.5 + 0.5 * rng.f64())
    }
}

/// Is this error worth retrying?  Transient markers follow what the
/// fault injector and the remote tier emit (and what real object-store
/// clients classify as retryable); anything else — missing blob, parse
/// error, checksum mismatch — is permanent and fails fast.
pub fn is_transient(msg: &str) -> bool {
    ["transient", "503", "SlowDown", "timed out", "timeout", "connection", "short read"]
        .iter()
        .any(|m| msg.contains(m))
}

/// Shared fault-handling telemetry: how often the retry/hedge machinery
/// actually engaged.  Flows into `RunReport` via the runner.
#[derive(Debug, Default)]
pub struct RetryStats {
    /// Re-attempts performed (attempt 2 and later).
    pub retries: AtomicU64,
    /// Hedged duplicate requests that beat the original.
    pub hedges_won: AtomicU64,
    /// Requests abandoned after exhausting attempts or the deadline.
    pub give_ups: AtomicU64,
}

impl RetryStats {
    pub fn record_retry(&self) {
        // ordering: Relaxed — monotonic telemetry counter; read
        // approximately live or after the pipeline joins.
        self.retries.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_hedge_won(&self) {
        // ordering: Relaxed — telemetry counter, as `record_retry`.
        self.hedges_won.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_give_up(&self) {
        // ordering: Relaxed — telemetry counter, as `record_retry`.
        self.give_ups.fetch_add(1, Ordering::Relaxed);
    }

    /// (retries, hedges_won, give_ups).
    pub fn snapshot(&self) -> (u64, u64, u64) {
        // ordering: Relaxed — approximate triple; the three counters
        // need no mutual consistency.
        (
            self.retries.load(Ordering::Relaxed),
            self.hedges_won.load(Ordering::Relaxed),
            self.give_ups.load(Ordering::Relaxed),
        )
    }
}

/// Run `op` under `policy`: retry transient failures with backoff until
/// success, attempts exhausted, the deadline passes, or a permanent
/// error surfaces.  `key` identifies the request for jitter replay
/// (e.g. a sample id or a name hash).
pub fn with_retry<T>(
    policy: &RetryPolicy,
    stats: &RetryStats,
    key: u64,
    mut op: impl FnMut() -> Result<T>,
) -> Result<T> {
    let t0 = Instant::now();
    let mut attempt = 1u32;
    loop {
        match op() {
            Ok(v) => return Ok(v),
            Err(e) => {
                let msg = format!("{e:#}");
                let give_up = attempt >= policy.attempts
                    || !is_transient(&msg)
                    || t0.elapsed().as_secs_f64() >= policy.deadline;
                if give_up {
                    if attempt > 1 {
                        stats.record_give_up();
                    }
                    return Err(e.context(format!("after {attempt} attempt(s)")));
                }
                attempt += 1;
                stats.record_retry();
                let backoff = policy.backoff_secs(attempt, key);
                if backoff > 0.0 {
                    std::thread::sleep(std::time::Duration::from_secs_f64(backoff));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_shapes() {
        assert!(!RetryPolicy::none().enabled());
        let p = RetryPolicy::with_retries(3, 30.0, 7);
        assert!(p.enabled());
        assert_eq!(p.attempts, 4);
        // Backoff grows but stays within [base/2, max].
        let b2 = p.backoff_secs(2, 1);
        let b5 = p.backoff_secs(5, 1);
        assert!(b2 >= p.base_backoff * 0.5 && b2 <= p.base_backoff, "{b2}");
        assert!(b5 <= p.max_backoff, "{b5}");
        // Deterministic by (seed, key, attempt); different keys decorrelate.
        assert_eq!(p.backoff_secs(3, 42), p.backoff_secs(3, 42));
        assert_ne!(p.backoff_secs(3, 42), p.backoff_secs(3, 43));
        assert_eq!(RetryPolicy::none().backoff_secs(2, 1), 0.0);
    }

    #[test]
    fn transient_classification() {
        assert!(is_transient("transient read error injected"));
        assert!(is_transient("503 SlowDown (throttled)"));
        assert!(is_transient("connection reset at offset 4096"));
        assert!(is_transient("request timed out"));
        assert!(!is_transient("no blob img/x.mjx"));
        assert!(!is_transient("record 7: checksum mismatch"));
    }

    #[test]
    fn with_retry_recovers_from_transient_failures() {
        let stats = RetryStats::default();
        let p = RetryPolicy {
            attempts: 4,
            base_backoff: 0.0,
            max_backoff: 0.0,
            deadline: f64::INFINITY,
            seed: 1,
        };
        let mut calls = 0;
        let out = with_retry(&p, &stats, 9, || {
            calls += 1;
            anyhow::ensure!(calls >= 3, "transient glitch {calls}");
            Ok(calls)
        })
        .unwrap();
        assert_eq!(out, 3);
        assert_eq!(stats.snapshot(), (2, 0, 0));
    }

    #[test]
    fn with_retry_fails_fast_on_permanent_errors() {
        let stats = RetryStats::default();
        let p = RetryPolicy::with_retries(5, 30.0, 1);
        let mut calls = 0;
        let err = with_retry(&p, &stats, 9, || -> Result<()> {
            calls += 1;
            anyhow::bail!("no blob x")
        })
        .unwrap_err();
        assert_eq!(calls, 1, "permanent errors must not be retried");
        assert!(format!("{err:#}").contains("no blob x"));
        assert_eq!(stats.snapshot(), (0, 0, 0));
    }

    #[test]
    fn with_retry_exhausts_attempts_and_reports_them() {
        let stats = RetryStats::default();
        let p = RetryPolicy {
            attempts: 3,
            base_backoff: 0.0,
            max_backoff: 0.0,
            deadline: f64::INFINITY,
            seed: 1,
        };
        let mut calls = 0;
        let err = with_retry(&p, &stats, 9, || -> Result<()> {
            calls += 1;
            anyhow::bail!("transient glitch")
        })
        .unwrap_err();
        assert_eq!(calls, 3);
        assert!(format!("{err:#}").contains("after 3 attempt(s)"), "{err:#}");
        assert_eq!(stats.snapshot(), (2, 0, 1));
    }

    #[test]
    fn with_retry_respects_deadline() {
        let stats = RetryStats::default();
        // Zero deadline: the first failure is already over budget.
        let p = RetryPolicy { deadline: 0.0, ..RetryPolicy::with_retries(10, 0.0, 1) };
        let mut calls = 0;
        let err = with_retry(&p, &stats, 9, || -> Result<()> {
            calls += 1;
            anyhow::bail!("transient glitch")
        })
        .unwrap_err();
        assert_eq!(calls, 1, "deadline must stop retrying: {err:#}");
    }
}
