//! Deterministic, seeded fault injection over any [`Storage`] tier.
//!
//! The paper's setting is preprocessing against *public-cloud* object
//! storage, where transient read errors, 503 SlowDown throttling,
//! straggler requests, and the occasional corrupted payload are normal
//! operating conditions — not exceptional ones.  [`FaultyStore`] wraps
//! any tier (dir/mem/s3/s3-cold, throttled or not) and injects exactly
//! those fault classes per a [`FaultProfile`], configured from the CLI
//! as `--faults off|spec`.
//!
//! **Replayability is the design constraint.**  Every fault decision is
//! a pure function of `(profile.seed, request key, k)` where the key
//! hashes `(name, offset, len)` and `k` counts how many times that exact
//! request has been made.  Two consequences:
//! * the *same seed replays the same faults* regardless of thread
//!   interleaving — a failing chaos run is a reproducible bug report;
//! * a retry of a failed request is the *next* occurrence `k+1`, so it
//!   redraws — transient faults are transient, exactly like the real
//!   thing, and the retry layer (`storage/retry.rs`) can be tested
//!   end to end.
//!
//! Fault classes (disjoint per draw, checked in this order):
//! * **transient** — the read fails with a retryable error;
//! * **throttle** — the read starts a 503 burst: it and the next
//!   `burst-1` reads through the store fail with `503 SlowDown`;
//! * **straggler** — the read succeeds but takes `slowdown`× the
//!   backing store's service time (the hedging target);
//! * **corrupt** — the read succeeds with one deterministic bit flipped
//!   (the quarantine/skip-budget target — checksums catch it downstream).

use super::Storage;
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// What to inject, with what probability.  Parsed from `--faults`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultProfile {
    /// Probability a read fails with a retryable transient error.
    pub transient: f64,
    /// Probability a read starts a 503 SlowDown burst.
    pub throttle: f64,
    /// Reads per 503 burst (the triggering read included).
    pub burst: u32,
    /// Probability a read is served `slowdown`x slower than the tier.
    pub straggler: f64,
    /// Straggler service-time multiplier (>= 1).
    pub slowdown: f64,
    /// Probability a read returns payload with one bit flipped.
    pub corrupt: f64,
    /// Fault seed: same seed, same faults.
    pub seed: u64,
}

impl Default for FaultProfile {
    fn default() -> Self {
        FaultProfile {
            transient: 0.0,
            throttle: 0.0,
            burst: 4,
            straggler: 0.0,
            slowdown: 10.0,
            corrupt: 0.0,
            seed: 0xFA_017,
        }
    }
}

impl FaultProfile {
    /// Parse the `--faults` value: `off` disables injection entirely;
    /// otherwise a comma list of `key=value` with keys `transient`,
    /// `throttle`, `burst`, `straggler`, `slowdown`, `corrupt`, `seed`
    /// (e.g. `transient=0.01,straggler=0.005,slowdown=20,seed=42`).
    pub fn parse(spec: &str) -> Result<Option<Self>> {
        if spec == "off" || spec.is_empty() {
            return Ok(None);
        }
        let mut p = FaultProfile::default();
        for kv in spec.split(',') {
            let (k, v) = kv
                .split_once('=')
                .with_context(|| format!("--faults entry {kv:?} is not key=value"))?;
            let num =
                |v: &str| v.parse::<f64>().with_context(|| format!("--faults {k}={v:?}: bad number"));
            match k {
                "transient" => p.transient = num(v)?,
                "throttle" => p.throttle = num(v)?,
                "burst" => p.burst = num(v)? as u32,
                "straggler" => p.straggler = num(v)?,
                "slowdown" => p.slowdown = num(v)?,
                "corrupt" => p.corrupt = num(v)?,
                "seed" => p.seed = num(v)? as u64,
                other => bail!(
                    "--faults key {other:?} unknown (want transient|throttle|burst|straggler|slowdown|corrupt|seed)"
                ),
            }
        }
        p.validate()?;
        Ok(Some(p))
    }

    pub fn validate(&self) -> Result<()> {
        for (name, rate) in [
            ("transient", self.transient),
            ("throttle", self.throttle),
            ("straggler", self.straggler),
            ("corrupt", self.corrupt),
        ] {
            ensure!((0.0..=1.0).contains(&rate), "--faults {name} must be in [0,1], got {rate}");
        }
        ensure!(
            self.transient + self.throttle + self.straggler + self.corrupt <= 1.0,
            "--faults rates must sum to <= 1 (disjoint classes per read)"
        );
        ensure!(self.slowdown >= 1.0, "--faults slowdown must be >= 1, got {}", self.slowdown);
        ensure!(self.burst >= 1, "--faults burst must be >= 1");
        Ok(())
    }

    /// Does this profile inject anything at all?
    pub fn active(&self) -> bool {
        self.transient > 0.0 || self.throttle > 0.0 || self.straggler > 0.0 || self.corrupt > 0.0
    }
}

/// Per-class injection counts (all monotonic).
#[derive(Debug, Default)]
pub struct FaultCounts {
    pub transient: AtomicU64,
    pub throttled: AtomicU64,
    pub stragglers: AtomicU64,
    pub corrupted: AtomicU64,
}

impl FaultCounts {
    /// Total faults injected so far (the run-report number).
    pub fn total(&self) -> u64 {
        // ordering: Relaxed — monotonic telemetry counters summed
        // approximately or after the pipeline joins.
        self.transient.load(Ordering::Relaxed)
            + self.throttled.load(Ordering::Relaxed)
            + self.stragglers.load(Ordering::Relaxed)
            + self.corrupted.load(Ordering::Relaxed)
    }
}

/// What one fault draw decided.
#[derive(Clone, Copy, Debug, PartialEq)]
enum Fault {
    None,
    Transient,
    ThrottleBurst,
    Straggler,
    Corrupt { bit: u64 },
}

/// Seeded fault-injecting wrapper over any inner store.
pub struct FaultyStore<S: Storage> {
    inner: S,
    profile: FaultProfile,
    counts: FaultCounts,
    /// k-th occurrence of each request key — the redraw index that makes
    /// transient faults transient under retry while staying replayable.
    occurrences: Mutex<HashMap<u64, u32>>,
    /// Reads left in the current 503 burst.
    burst_left: Mutex<u32>,
}

impl<S: Storage> FaultyStore<S> {
    pub fn new(inner: S, profile: FaultProfile) -> Self {
        FaultyStore {
            inner,
            profile,
            counts: FaultCounts::default(),
            occurrences: Mutex::new(HashMap::new()),
            burst_left: Mutex::new(0),
        }
    }

    pub fn counts(&self) -> &FaultCounts {
        &self.counts
    }

    pub fn profile(&self) -> FaultProfile {
        self.profile
    }

    /// FNV-1a over the request identity (name, offset, len).
    fn request_key(name: &str, offset: u64, len: u64) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |b: u8| {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x1_0000_01b3);
        };
        name.bytes().for_each(&mut eat);
        offset.to_le_bytes().iter().copied().for_each(&mut eat);
        len.to_le_bytes().iter().copied().for_each(&mut eat);
        h
    }

    /// Draw the fault for occurrence `k` of request `key` — a pure
    /// function of (seed, key, k), independent of thread interleaving.
    fn draw(&self, key: u64, k: u32, payload_bits: u64) -> Fault {
        let p = &self.profile;
        let salt = key
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(u64::from(k).wrapping_mul(0xBF58_476D_1CE4_E5B9));
        let mut rng = Rng::new(p.seed ^ salt);
        let u = rng.f64();
        if u < p.transient {
            Fault::Transient
        } else if u < p.transient + p.throttle {
            Fault::ThrottleBurst
        } else if u < p.transient + p.throttle + p.straggler {
            Fault::Straggler
        } else if u < p.transient + p.throttle + p.straggler + p.corrupt && payload_bits > 0 {
            Fault::Corrupt { bit: rng.gen_range(payload_bits) }
        } else {
            Fault::None
        }
    }

    /// Shared fault path for `read`/`read_range`: decide, then either
    /// fail, slow, or corrupt the inner read.
    fn faulted_read(
        &self,
        name: &str,
        offset: u64,
        len_hint: u64,
        fetch: impl FnOnce() -> Result<Arc<[u8]>>,
    ) -> Result<Arc<[u8]>> {
        // An active burst throttles every read through the store,
        // whatever its own draw would have been — that is what SlowDown
        // does to a prefix of the request stream.
        {
            // poison: only integer bookkeeping runs under the lock.
            let mut left = self.burst_left.lock().unwrap();
            if *left > 0 {
                *left -= 1;
                // ordering: Relaxed — telemetry counter (see FaultCounts).
                self.counts.throttled.fetch_add(1, Ordering::Relaxed);
                bail!("injected: 503 SlowDown (throttled; {left} more in burst) for {name}@{offset}");
            }
        }
        let key = Self::request_key(name, offset, len_hint);
        let k = {
            // poison: only a HashMap counter bump runs under the lock.
            let mut occ = self.occurrences.lock().unwrap();
            let e = occ.entry(key).or_insert(0);
            *e += 1;
            *e
        };
        match self.draw(key, k, len_hint.saturating_mul(8)) {
            Fault::None => fetch(),
            Fault::Transient => {
                // ordering: Relaxed — telemetry counter (see FaultCounts).
                self.counts.transient.fetch_add(1, Ordering::Relaxed);
                bail!("injected: transient read error for {name}@{offset} (attempt {k})")
            }
            Fault::ThrottleBurst => {
                {
                    // poison: integer store under the lock, no panic source.
                    let mut left = self.burst_left.lock().unwrap();
                    *left = self.profile.burst.saturating_sub(1);
                }
                // ordering: Relaxed — telemetry counter (see FaultCounts).
                self.counts.throttled.fetch_add(1, Ordering::Relaxed);
                bail!("injected: 503 SlowDown (burst start) for {name}@{offset}")
            }
            Fault::Straggler => {
                // Pay (slowdown - 1)x the tier's real service time on
                // top of the read itself — a straggler, not an error.
                let t0 = Instant::now();
                let out = fetch()?;
                let extra = t0.elapsed().as_secs_f64() * (self.profile.slowdown - 1.0);
                if extra > 0.0 {
                    std::thread::sleep(Duration::from_secs_f64(extra));
                }
                // ordering: Relaxed — telemetry counter (see FaultCounts).
                self.counts.stragglers.fetch_add(1, Ordering::Relaxed);
                Ok(out)
            }
            Fault::Corrupt { bit } => {
                let clean = fetch()?;
                let mut bytes = clean.to_vec();
                let idx = (bit / 8) as usize;
                if idx < bytes.len() {
                    bytes[idx] ^= 1 << (bit % 8);
                }
                // ordering: Relaxed — telemetry counter (see FaultCounts).
                self.counts.corrupted.fetch_add(1, Ordering::Relaxed);
                Ok(bytes.into())
            }
        }
    }
}

impl<S: Storage> Storage for FaultyStore<S> {
    fn read(&self, name: &str) -> Result<Arc<[u8]>> {
        let len = self.inner.len(name).unwrap_or(0);
        self.faulted_read(name, 0, len, || self.inner.read(name))
    }

    fn read_range(&self, name: &str, offset: u64, len: u64) -> Result<Arc<[u8]>> {
        self.faulted_read(name, offset, len, || self.inner.read_range(name, offset, len))
    }

    // Metadata stays reliable: fault injection targets the data path,
    // where retries/hedging/quarantine live — a flaky `list` would fail
    // runs before the machinery under test ever engages.
    fn len(&self, name: &str) -> Result<u64> {
        self.inner.len(name)
    }

    fn list(&self) -> Result<Vec<String>> {
        self.inner.list()
    }

    fn stats(&self) -> (u64, u64) {
        self.inner.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::retry::is_transient;
    use crate::storage::MemStore;

    fn store_with(profile: FaultProfile) -> FaultyStore<MemStore> {
        let m = MemStore::new();
        m.write("a", (0u8..=255).cycle().take(4096).collect::<Vec<u8>>());
        FaultyStore::new(m, profile)
    }

    #[test]
    fn parse_off_and_specs() {
        assert!(FaultProfile::parse("off").unwrap().is_none());
        assert!(FaultProfile::parse("").unwrap().is_none());
        let p = FaultProfile::parse("transient=0.01,straggler=0.005,slowdown=20,seed=42")
            .unwrap()
            .unwrap();
        assert_eq!(p.transient, 0.01);
        assert_eq!(p.straggler, 0.005);
        assert_eq!(p.slowdown, 20.0);
        assert_eq!(p.seed, 42);
        assert!(p.active());
        assert!(FaultProfile::parse("transient=2").is_err(), "rate > 1 must be rejected");
        assert!(FaultProfile::parse("bogus=1").is_err());
        assert!(FaultProfile::parse("transient").is_err(), "missing =value");
        assert!(FaultProfile::parse("slowdown=0.5,straggler=0.1").is_err());
    }

    #[test]
    fn same_seed_replays_the_same_faults() {
        let profile =
            FaultProfile { transient: 0.2, seed: 99, ..FaultProfile::default() };
        let run = || {
            let s = store_with(profile);
            (0..200u64)
                .map(|i| s.read_range("a", i * 16, 16).is_err())
                .collect::<Vec<bool>>()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same seed must inject identical faults");
        let n = a.iter().filter(|&&e| e).count();
        assert!(n > 10 && n < 100, "≈20% of 200 reads should fail, got {n}");
    }

    #[test]
    fn different_seeds_decorrelate() {
        let mk = |seed| {
            let s = store_with(FaultProfile {
                transient: 0.2,
                seed,
                ..FaultProfile::default()
            });
            (0..200u64)
                .map(|i| s.read_range("a", i * 16, 16).is_err())
                .collect::<Vec<bool>>()
        };
        assert_ne!(mk(1), mk(2));
    }

    #[test]
    fn retrying_a_transient_fault_redraws() {
        // transient=0.5: a failed request retried enough times succeeds,
        // because occurrence k+1 is a fresh draw.
        let s = store_with(FaultProfile { transient: 0.5, seed: 3, ..FaultProfile::default() });
        let mut recovered = 0;
        for i in 0..50u64 {
            let mut ok = false;
            for _ in 0..16 {
                if s.read_range("a", i * 64, 64).is_ok() {
                    ok = true;
                    break;
                }
            }
            assert!(ok, "16 redraws at p=0.5 must succeed (read {i})");
            recovered += 1;
        }
        assert_eq!(recovered, 50);
        assert!(s.counts().total() > 0);
    }

    #[test]
    fn injected_errors_classify_as_transient() {
        let s = store_with(FaultProfile { transient: 1.0, seed: 1, ..FaultProfile::default() });
        let err = s.read_range("a", 0, 64).unwrap_err();
        assert!(is_transient(&format!("{err:#}")), "{err:#}");
        let s = store_with(FaultProfile { throttle: 1.0, seed: 1, ..FaultProfile::default() });
        let err = s.read_range("a", 0, 64).unwrap_err();
        assert!(is_transient(&format!("{err:#}")), "{err:#}");
    }

    #[test]
    fn throttle_bursts_fail_following_reads() {
        let s = store_with(FaultProfile {
            throttle: 1.0,
            burst: 3,
            seed: 5,
            ..FaultProfile::default()
        });
        // Burst start + 2 follow-ups, then (throttle=1.0) a new burst —
        // every read fails, and the counter sees each one.
        for i in 0..6u64 {
            assert!(s.read_range("a", i * 8, 8).is_err(), "read {i}");
        }
        // ordering: Relaxed — test-side counter read after the calls.
        assert_eq!(s.counts().throttled.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn corruption_flips_exactly_one_bit() {
        let s = store_with(FaultProfile { corrupt: 1.0, seed: 7, ..FaultProfile::default() });
        let clean: Vec<u8> = (0u8..=255).cycle().take(4096).collect();
        let got = s.read_range("a", 0, 4096).unwrap();
        let diff: u32 = clean
            .iter()
            .zip(got.iter())
            .map(|(a, b)| (a ^ b).count_ones())
            .sum();
        assert_eq!(diff, 1, "exactly one bit must flip");
        // Replay: same request, next occurrence — same seed still fully
        // corrupts (rate 1.0), and the flipped bit is deterministic for
        // a fixed occurrence index.
        let again = s.read_range("a", 0, 4096).unwrap();
        let s2 = store_with(FaultProfile { corrupt: 1.0, seed: 7, ..FaultProfile::default() });
        let _ = s2.read_range("a", 0, 4096).unwrap();
        let again2 = s2.read_range("a", 0, 4096).unwrap();
        assert_eq!(again[..], again2[..], "occurrence-indexed corruption must replay");
    }

    #[test]
    fn inactive_profile_is_transparent() {
        let s = store_with(FaultProfile::default());
        assert!(!s.profile().active());
        for i in 0..64u64 {
            assert!(s.read_range("a", i * 8, 8).is_ok());
        }
        assert_eq!(s.counts().total(), 0);
        assert_eq!(s.read("a").unwrap().len(), 4096);
        assert_eq!(s.list().unwrap(), vec!["a".to_string()]);
    }
}
