//! Parallel range-GET prefetcher (s3bfg-style): split an object into
//! aligned parts, fan the parts across N worker threads as concurrent
//! ranged reads, and deliver the bytes *in order* through a bounded
//! sliding window.
//!
//! On a remote tier (`storage/remote.rs`) each ranged read pays the
//! network's first-byte latency; issuing `conns` of them concurrently
//! hides latency behind transfer, which is the standard cure for fetch
//! stalls when training data lives in object storage.  Two entry points:
//!
//! * [`PrefetchReader`] — `std::io::Read` adapter, drop-in for the serial
//!   `StorageReader` in `pipeline/source.rs`; bounded readahead window.
//! * [`fetch_parallel`] — whole-object fetch with an unbounded window.
//!
//! The scheduler is a Mutex+Condvar sliding window, not a channel: workers
//! may finish parts out of order, and the reader must block on exactly the
//! next part while the window bound keeps workers from racing ahead of the
//! consumer by more than `window_parts` parts.

use super::Storage;
use crate::metrics::trace::{Stage, Tracer};
use crate::metrics::Gauge;
use anyhow::{ensure, Context, Result};
use std::collections::BTreeMap;
use std::io::Read;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// How a shard/object stream is parallelized.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PrefetchPlan {
    /// Concurrent ranged reads (worker threads). 1 = serial.
    pub conns: usize,
    /// Aligned part size in bytes (one ranged GET per part).
    pub part_size: usize,
    /// Max parts fetched ahead of the consumer (>= conns to keep every
    /// connection busy).
    pub window_parts: usize,
}

impl PrefetchPlan {
    /// Plan for `conns` connections reading `part_size`-byte parts with a
    /// `readahead_bytes` window (clamped so the window covers the pool).
    pub fn new(conns: usize, part_size: usize, readahead_bytes: usize) -> Self {
        let conns = conns.max(1);
        let part_size = part_size.max(1);
        let window_parts = (readahead_bytes / part_size).max(conns);
        PrefetchPlan { conns, part_size, window_parts }
    }

    /// Serial fallback: one connection, no readahead beyond one part.
    pub fn serial(part_size: usize) -> Self {
        PrefetchPlan { conns: 1, part_size: part_size.max(1), window_parts: 1 }
    }

    pub fn is_serial(&self) -> bool {
        self.conns <= 1
    }
}

struct State {
    /// Next part index to hand to a worker.
    next_issue: usize,
    /// Next part index the reader will consume.
    next_deliver: usize,
    n_parts: usize,
    /// Completed parts waiting for in-order delivery.
    done: BTreeMap<usize, Arc<[u8]>>,
    error: Option<String>,
    cancelled: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Reader waits here for the next in-order part.
    avail: Condvar,
    /// Workers wait here for window space.
    space: Condvar,
    /// Completed-parts queue depth (level + peak).
    depth: Gauge,
}

fn worker_loop(
    shared: &Shared,
    store: &dyn Storage,
    name: &str,
    plan: PrefetchPlan,
    len: u64,
    tracer: &Tracer,
) {
    loop {
        let idx = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.cancelled || st.error.is_some() || st.next_issue >= st.n_parts {
                    return;
                }
                if st.next_issue < st.next_deliver + plan.window_parts {
                    break;
                }
                st = shared.space.wait(st).unwrap();
            }
            let i = st.next_issue;
            st.next_issue += 1;
            i
        };
        let offset = idx as u64 * plan.part_size as u64;
        let want = (plan.part_size as u64).min(len - offset);
        // One Fetch span per ranged GET, sample = part index — on a
        // remote tier this is where fetch-stall time actually lives.
        let span = tracer.start();
        let got = store.read_range(name, offset, want);
        tracer.record(Stage::Fetch, idx as u64, span);
        match got {
            Ok(bytes) => {
                let short = (bytes.len() as u64) < want;
                let mut st = shared.state.lock().unwrap();
                if short && st.error.is_none() {
                    st.error = Some(format!(
                        "short read of {name}: part {idx} got {} of {want} bytes",
                        bytes.len()
                    ));
                } else {
                    st.done.insert(idx, bytes);
                    shared.depth.set(st.done.len() as u64);
                }
                shared.avail.notify_all();
                shared.space.notify_all();
            }
            Err(e) => {
                let mut st = shared.state.lock().unwrap();
                if st.error.is_none() {
                    st.error = Some(format!("{e:#}"));
                }
                shared.avail.notify_all();
                shared.space.notify_all();
                return;
            }
        }
    }
}

/// Ordered `Read` over an object fetched by concurrent ranged reads.
pub struct PrefetchReader {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    current: Arc<[u8]>,
    pos: usize,
}

impl PrefetchReader {
    pub fn open(store: Arc<dyn Storage>, name: &str, plan: PrefetchPlan) -> Result<Self> {
        Self::open_traced(store, name, plan, Tracer::off())
    }

    /// [`open`](Self::open) with a span recorder: each worker's ranged
    /// GETs become `fetch` spans on that worker's own trace track.
    pub fn open_traced(
        store: Arc<dyn Storage>,
        name: &str,
        plan: PrefetchPlan,
        tracer: Tracer,
    ) -> Result<Self> {
        let len = store.len(name).with_context(|| format!("len of {name}"))?;
        let n_parts = (len as usize).div_ceil(plan.part_size);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                next_issue: 0,
                next_deliver: 0,
                n_parts,
                done: BTreeMap::new(),
                error: None,
                cancelled: false,
            }),
            avail: Condvar::new(),
            space: Condvar::new(),
            depth: Gauge::new(),
        });
        let n_workers = plan.conns.min(n_parts.max(1));
        let mut workers = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let shared_w = shared.clone();
            let store = store.clone();
            let name = name.to_string();
            let tracer = tracer.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("prefetch-{w}"))
                .spawn(move || {
                    worker_loop(&shared_w, store.as_ref(), &name, plan, len, &tracer)
                });
            match spawned {
                Ok(h) => workers.push(h),
                Err(e) => {
                    // A partial pool must not leak: cancel and reap the
                    // workers already running before surfacing the error.
                    shared.state.lock().unwrap().cancelled = true;
                    shared.space.notify_all();
                    shared.avail.notify_all();
                    for h in workers {
                        let _ = h.join();
                    }
                    return Err(e).with_context(|| format!("spawn prefetch worker {w}"));
                }
            }
        }
        Ok(PrefetchReader { shared, workers, current: Arc::from(&[][..]), pos: 0 })
    }

    /// Completed-parts queue depth gauge (level + high-water mark).
    pub fn queue_depth(&self) -> &Gauge {
        &self.shared.depth
    }

    /// Block until the next in-order part is ready; Ok(false) = EOF.
    fn next_part(&mut self) -> std::io::Result<bool> {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if let Some(bytes) = st.done.remove(&st.next_deliver) {
                st.next_deliver += 1;
                self.shared.depth.set(st.done.len() as u64);
                drop(st);
                self.shared.space.notify_all();
                self.current = bytes;
                self.pos = 0;
                return Ok(true);
            }
            if let Some(e) = &st.error {
                return Err(std::io::Error::other(e.clone()));
            }
            if st.next_deliver >= st.n_parts {
                return Ok(false); // clean EOF
            }
            st = self.shared.avail.wait(st).unwrap();
        }
    }
}

impl Read for PrefetchReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        while self.pos >= self.current.len() {
            if !self.next_part()? {
                return Ok(0);
            }
        }
        let n = buf.len().min(self.current.len() - self.pos);
        buf[..n].copy_from_slice(&self.current[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

impl Drop for PrefetchReader {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.cancelled = true;
        }
        self.shared.space.notify_all();
        self.shared.avail.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Fetch a whole object with `conns` concurrent ranged reads (unbounded
/// window, s3bfg's whole-file mode).  Returns the reassembled bytes.
pub fn fetch_parallel(
    store: Arc<dyn Storage>,
    name: &str,
    conns: usize,
    part_size: usize,
) -> Result<Vec<u8>> {
    let len = store.len(name)? as usize;
    let plan = PrefetchPlan { conns: conns.max(1), part_size: part_size.max(1), window_parts: usize::MAX / 2 };
    let mut r = PrefetchReader::open(store, name, plan)?;
    let mut out = Vec::with_capacity(len);
    r.read_to_end(&mut out)?;
    ensure!(out.len() == len, "fetched {} of {len} bytes of {name}", out.len());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::MemStore;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn blob(n: usize) -> Vec<u8> {
        // Position-dependent bytes so reordering bugs corrupt the data.
        (0..n).map(|i| (i % 251) as u8 ^ (i / 7919) as u8).collect()
    }

    fn mem(name: &str, data: Vec<u8>) -> Arc<dyn Storage> {
        let m = MemStore::new();
        m.write(name, data);
        Arc::new(m)
    }

    #[test]
    fn reader_reassembles_in_order() {
        // Odd length so the tail part is short.
        let data = blob(1_000_003);
        let store = mem("b", data.clone());
        for (conns, part) in [(1, 4096), (4, 4096), (8, 65_536), (3, 1_000_003), (4, 2_000_000)] {
            let plan = PrefetchPlan::new(conns, part, 8 * part);
            let mut r = PrefetchReader::open(store.clone(), "b", plan).unwrap();
            let mut out = Vec::new();
            r.read_to_end(&mut out).unwrap();
            assert_eq!(out, data, "conns={conns} part={part}");
        }
    }

    #[test]
    fn empty_object_is_clean_eof() {
        let store = mem("e", Vec::new());
        let mut r = PrefetchReader::open(store, "e", PrefetchPlan::new(4, 1024, 8192)).unwrap();
        let mut out = Vec::new();
        r.read_to_end(&mut out).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn fetch_parallel_roundtrips() {
        let data = blob(777_777);
        let store = mem("b", data.clone());
        assert_eq!(fetch_parallel(store.clone(), "b", 8, 65_536).unwrap(), data);
        assert_eq!(fetch_parallel(store, "b", 1, 1 << 20).unwrap(), data);
    }

    #[test]
    fn window_bounds_readahead() {
        // 100 parts, window 4: after the reader consumes nothing, at most
        // window parts may complete.
        let data = blob(100 * 1024);
        let store = mem("b", data);
        let plan = PrefetchPlan { conns: 4, part_size: 1024, window_parts: 4 };
        let r = PrefetchReader::open(store, "b", plan).unwrap();
        // Give workers ample time (even descheduled on a loaded CI box)
        // to fill — and try to overfill — the window.
        std::thread::sleep(std::time::Duration::from_millis(150));
        let depth = r.queue_depth().peak();
        assert!(depth <= 4, "window overrun: {depth} parts buffered");
        assert!(depth >= 1, "nothing prefetched");
    }

    #[test]
    fn plan_window_covers_pool() {
        let p = PrefetchPlan::new(8, 1 << 20, 2 << 20);
        assert_eq!(p.window_parts, 8, "window must cover the connection pool");
        let p = PrefetchPlan::new(2, 1 << 20, 8 << 20);
        assert_eq!(p.window_parts, 8);
        assert!(PrefetchPlan::serial(4096).is_serial());
    }

    /// Storage that fails every read past a byte offset.
    struct FailAfter {
        inner: MemStore,
        limit: u64,
        reads: AtomicU64,
    }

    impl Storage for FailAfter {
        fn read(&self, name: &str) -> Result<Arc<[u8]>> {
            self.inner.read(name)
        }
        fn read_range(&self, name: &str, offset: u64, len: u64) -> Result<Arc<[u8]>> {
            self.reads.fetch_add(1, Ordering::Relaxed);
            anyhow::ensure!(offset < self.limit, "connection reset at offset {offset}");
            self.inner.read_range(name, offset, len)
        }
        fn len(&self, name: &str) -> Result<u64> {
            self.inner.len(name)
        }
        fn list(&self) -> Result<Vec<String>> {
            self.inner.list()
        }
        fn stats(&self) -> (u64, u64) {
            self.inner.stats()
        }
    }

    #[test]
    fn worker_error_surfaces_to_reader() {
        let inner = MemStore::new();
        inner.write("b", blob(64 * 1024));
        let store: Arc<dyn Storage> =
            Arc::new(FailAfter { inner, limit: 16 * 1024, reads: AtomicU64::new(0) });
        let mut r =
            PrefetchReader::open(store, "b", PrefetchPlan::new(4, 4096, 16 * 4096)).unwrap();
        let mut out = Vec::new();
        let err = r.read_to_end(&mut out).unwrap_err();
        assert!(err.to_string().contains("connection reset"), "{err}");
    }

    #[test]
    fn dropping_mid_stream_does_not_hang() {
        let data = blob(512 * 1024);
        let store = mem("b", data);
        let mut r =
            PrefetchReader::open(store, "b", PrefetchPlan::new(4, 4096, 8 * 4096)).unwrap();
        let mut buf = [0u8; 1000];
        let n = r.read(&mut buf).unwrap();
        assert!(n > 0);
        drop(r); // must cancel workers and join without deadlock
    }

    /// A traced reader turns every ranged GET into a `fetch` span on the
    /// issuing worker's track, tagged with the part index.
    #[test]
    fn traced_reader_records_fetch_spans() {
        use crate::metrics::trace::{Stage, Tracer};
        let data = blob(16 * 1024);
        let store = mem("b", data.clone());
        let tracer = Tracer::new(1.0);
        let plan = PrefetchPlan::new(2, 4096, 8 * 4096); // 4 parts
        let mut r =
            PrefetchReader::open_traced(store, "b", plan, tracer.clone()).unwrap();
        let mut out = Vec::new();
        r.read_to_end(&mut out).unwrap();
        assert_eq!(out, data);
        drop(r); // join the workers before draining their rings
        let dump = tracer.drain();
        let mut parts: Vec<u64> = dump
            .tracks
            .iter()
            .flat_map(|t| t.spans.iter())
            .filter(|s| s.stage == Stage::Fetch)
            .map(|s| s.sample)
            .collect();
        parts.sort();
        assert_eq!(parts, vec![0, 1, 2, 3], "one fetch span per part");
        assert!(
            dump.tracks.iter().any(|t| t.label.starts_with("prefetch-")),
            "spans must land on the prefetch workers' tracks"
        );
    }
}
