//! Parallel range-GET prefetcher (s3bfg-style): split an object into
//! aligned parts, fan the parts across N worker threads as concurrent
//! ranged reads, and deliver the bytes *in order* through a bounded
//! sliding window.
//!
//! On a remote tier (`storage/remote.rs`) each ranged read pays the
//! network's first-byte latency; issuing `conns` of them concurrently
//! hides latency behind transfer, which is the standard cure for fetch
//! stalls when training data lives in object storage.  Two entry points:
//!
//! * [`PrefetchReader`] — `std::io::Read` adapter, drop-in for the serial
//!   `StorageReader` in `pipeline/source.rs`; bounded readahead window.
//! * [`fetch_parallel`] — whole-object fetch with an unbounded window.
//!
//! The scheduler is a Mutex+Condvar sliding window, not a channel: workers
//! may finish parts out of order, and the reader must block on exactly the
//! next part while the window bound keeps workers from racing ahead of the
//! consumer by more than `window_parts` parts.
//!
//! ## Fault tolerance ([`Resilience`])
//!
//! Object stores fail; a prefetcher that wedges its whole window on one
//! failed or straggling part turns a transient blip into a dead epoch.
//! With a [`Resilience`] policy attached:
//!
//! * **window re-issue** — a part whose ranged GET fails transiently is
//!   pushed back into the scheduler with a backoff deadline instead of
//!   poisoning the stream; *any* idle worker re-issues it when its
//!   backoff expires, so the failed connection never parks the window.
//!   Attempts and per-part wall time are bounded by the
//!   [`RetryPolicy`]; exhaustion (or a permanent error) still fails the
//!   stream with a part-and-attempt-count diagnosis.
//! * **hedged GETs** — once enough parts have completed to estimate a
//!   trailing p95 latency, an idle worker duplicates the oldest
//!   in-flight part that has been outstanding longer than that p95.
//!   First answer wins; the loser's bytes are discarded on arrival (a
//!   blocking read cannot be aborted mid-flight, so "cancelled" means
//!   its result is dropped and its connection returns to the pool).
//!
//! Retried and hedged attempts are recorded as [`Stage::Retry`] spans so
//! the Chrome trace shows exactly where the fault machinery engaged;
//! first attempts stay [`Stage::Fetch`].

use super::retry::{is_transient, RetryPolicy, RetryStats};
use super::Storage;
use crate::metrics::trace::{Stage, Tracer};
use crate::metrics::Gauge;
use anyhow::{ensure, Context, Result};
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::Read;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How a shard/object stream is parallelized.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PrefetchPlan {
    /// Concurrent ranged reads (worker threads). 1 = serial.
    pub conns: usize,
    /// Aligned part size in bytes (one ranged GET per part).
    pub part_size: usize,
    /// Max parts fetched ahead of the consumer (>= conns to keep every
    /// connection busy).
    pub window_parts: usize,
}

impl PrefetchPlan {
    /// Plan for `conns` connections reading `part_size`-byte parts with a
    /// `readahead_bytes` window (clamped so the window covers the pool).
    pub fn new(conns: usize, part_size: usize, readahead_bytes: usize) -> Self {
        let conns = conns.max(1);
        let part_size = part_size.max(1);
        let window_parts = (readahead_bytes / part_size).max(conns);
        PrefetchPlan { conns, part_size, window_parts }
    }

    /// Serial fallback: one connection, no readahead beyond one part.
    pub fn serial(part_size: usize) -> Self {
        PrefetchPlan { conns: 1, part_size: part_size.max(1), window_parts: 1 }
    }

    pub fn is_serial(&self) -> bool {
        self.conns <= 1
    }
}

/// Completed-latency samples needed before hedging may engage (a p95
/// from fewer observations is noise, and hedging on noise doubles load
/// for nothing).
const HEDGE_MIN_SAMPLES: usize = 8;
/// Floor on the hedge trigger: never duplicate a part that has been in
/// flight for less than this, whatever the trailing p95 says.
const HEDGE_MIN_SECS: f64 = 1e-3;
/// Trailing-latency window size for the p95 estimate.
const LATENCY_WINDOW: usize = 64;

/// Fault-handling policy for a prefetch stream: bounded retry with
/// backoff for failed parts, optional hedged duplicates for stragglers,
/// shared counters for the run report.
#[derive(Clone)]
pub struct Resilience {
    pub retry: RetryPolicy,
    pub hedge: bool,
    pub stats: Arc<RetryStats>,
}

impl Resilience {
    /// The pre-fault-layer behavior: no retry, no hedging.
    pub fn none() -> Self {
        Resilience { retry: RetryPolicy::none(), hedge: false, stats: Arc::default() }
    }

    pub fn new(retry: RetryPolicy, hedge: bool, stats: Arc<RetryStats>) -> Self {
        Resilience { retry, hedge, stats }
    }
}

/// One in-flight part: issue times and how many copies are racing.
struct Inflight {
    /// Seconds (since stream start) the *current primary* was issued —
    /// the age the hedger compares against the trailing p95.
    since: f64,
    copies: u32,
    hedged: bool,
}

struct State {
    /// Next part index to hand to a worker.
    next_issue: usize,
    /// Next part index the reader will consume.
    next_deliver: usize,
    n_parts: usize,
    /// Completed parts waiting for in-order delivery.
    done: BTreeMap<usize, Arc<[u8]>>,
    /// Parts currently being fetched (by at least one worker).
    inflight: HashMap<usize, Inflight>,
    /// Transient-failed parts awaiting re-issue: (part, not-before secs).
    retry_queue: Vec<(usize, f64)>,
    /// Per-part (attempts so far, first-issue secs) — cleared on success.
    attempts: HashMap<usize, (u32, f64)>,
    /// Trailing completed-part latencies for the hedge p95.
    latencies: VecDeque<f64>,
    error: Option<String>,
    cancelled: bool,
}

impl State {
    /// Trailing p95 of completed-part latencies (`None` until enough
    /// samples arrived for the estimate to mean anything).
    fn hedge_threshold(&self) -> Option<f64> {
        if self.latencies.len() < HEDGE_MIN_SAMPLES {
            return None;
        }
        let mut v: Vec<f64> = self.latencies.iter().copied().collect();
        v.sort_by(|a, b| a.total_cmp(b));
        let idx = ((v.len() as f64) * 0.95) as usize;
        Some(v[idx.min(v.len() - 1)].max(HEDGE_MIN_SECS))
    }
}

struct Shared {
    state: Mutex<State>,
    /// Reader waits here for the next in-order part.
    avail: Condvar,
    /// Workers wait here for window space / retry deadlines / hedge ages.
    space: Condvar,
    /// Completed-parts queue depth (level + peak).
    depth: Gauge,
    t0: Instant,
    res: Resilience,
}

/// One unit of worker work: which part, which attempt, primary or hedge.
struct Job {
    idx: usize,
    attempt: u32,
    hedge: bool,
    issued_at: f64,
}

/// Pick the next job under the scheduler lock: ripe retries first (a
/// failed part must not starve behind fresh issues), then fresh parts
/// within the window, then hedge candidates.  Blocks when nothing is
/// actionable; returns `None` when the stream is finished, failed, or
/// cancelled.
fn next_job(shared: &Shared, plan: PrefetchPlan) -> Option<Job> {
    // poison: scheduler state only — no user code panics under the lock;
    // a poisoned scheduler means a crashed sibling worker and the whole
    // stream is already lost.
    let mut st = shared.state.lock().unwrap();
    loop {
        if st.cancelled || st.error.is_some() {
            return None;
        }
        // Every part issued, nothing pending anywhere: the stream is
        // complete (parts still in `done` are the reader's business).
        if st.next_issue >= st.n_parts && st.retry_queue.is_empty() && st.inflight.is_empty() {
            return None;
        }
        let now = shared.t0.elapsed().as_secs_f64();
        // 1. A failed part whose backoff expired.
        if let Some(pos) = st.retry_queue.iter().position(|&(_, nb)| nb <= now) {
            let (idx, _) = st.retry_queue.swap_remove(pos);
            let e = st.attempts.entry(idx).or_insert((0, now));
            e.0 += 1;
            let attempt = e.0;
            st.inflight.insert(idx, Inflight { since: now, copies: 1, hedged: false });
            return Some(Job { idx, attempt, hedge: false, issued_at: now });
        }
        // 2. A fresh part within the sliding window.
        if st.next_issue < st.n_parts && st.next_issue < st.next_deliver + plan.window_parts {
            let idx = st.next_issue;
            st.next_issue += 1;
            st.attempts.insert(idx, (1, now));
            st.inflight.insert(idx, Inflight { since: now, copies: 1, hedged: false });
            return Some(Job { idx, attempt: 1, hedge: false, issued_at: now });
        }
        // 3. Hedge the oldest straggler past the trailing p95.
        let threshold = if shared.res.hedge { st.hedge_threshold() } else { None };
        if let Some(thr) = threshold {
            let cand = st
                .inflight
                .iter()
                .filter(|(_, p)| p.copies == 1 && !p.hedged && now - p.since >= thr)
                .map(|(&idx, p)| (idx, p.since))
                .min_by(|a, b| a.1.total_cmp(&b.1));
            if let Some((idx, _)) = cand {
                let attempt = st.attempts.get(&idx).map_or(1, |e| e.0);
                let p = st.inflight.get_mut(&idx).expect("candidate came from inflight");
                p.hedged = true;
                p.copies += 1;
                return Some(Job { idx, attempt, hedge: true, issued_at: now });
            }
        }
        // 4. Nothing actionable: sleep until the nearest deadline (a
        // retry's backoff or a straggler crossing the hedge threshold),
        // or indefinitely when neither exists.
        let mut wake: Option<f64> = st.retry_queue.iter().map(|&(_, nb)| nb).fold(None, |a, b| {
            Some(a.map_or(b, |a: f64| a.min(b)))
        });
        if let Some(thr) = threshold {
            let oldest = st
                .inflight
                .values()
                .filter(|p| p.copies == 1 && !p.hedged)
                .map(|p| p.since + thr)
                .fold(f64::INFINITY, f64::min);
            if oldest.is_finite() {
                wake = Some(wake.map_or(oldest, |w| w.min(oldest)));
            }
        }
        st = match wake {
            Some(at) => {
                let dur = Duration::from_secs_f64((at - now).clamp(1e-4, 0.05));
                // poison: see the lock at the top of `next_job`.
                shared.space.wait_timeout(st, dur).unwrap().0
            }
            // poison: see the lock at the top of `next_job`.
            None => shared.space.wait(st).unwrap(),
        };
    }
}

/// Handle one finished attempt: deliver a winning read, discard a losing
/// hedge, re-queue a transient failure with backoff, or fail the stream.
fn complete(
    shared: &Shared,
    name: &str,
    job: &Job,
    want: u64,
    got: Result<Arc<[u8]>>,
) {
    let now = shared.t0.elapsed().as_secs_f64();
    // poison: see `next_job` — scheduler bookkeeping only.
    let mut st = shared.state.lock().unwrap();
    let remaining = match st.inflight.get_mut(&job.idx) {
        Some(p) => {
            p.copies = p.copies.saturating_sub(1);
            let left = p.copies;
            if left == 0 {
                st.inflight.remove(&job.idx);
            }
            left
        }
        None => 0, // the race was already decided and cleaned up
    };
    let already_delivered = job.idx < st.next_deliver || st.done.contains_key(&job.idx);
    let outcome = match got {
        Ok(bytes) if bytes.len() as u64 == want => Ok(bytes),
        Ok(bytes) => Err(format!(
            "short read of {name}: part {} got {} of {want} bytes",
            job.idx,
            bytes.len()
        )),
        Err(e) => Err(format!("{e:#}")),
    };
    match outcome {
        Ok(bytes) => {
            if already_delivered {
                // Losing copy of a hedged race: first answer already won;
                // "cancelling" the loser is dropping its bytes here.
                shared.space.notify_all();
                return;
            }
            if st.latencies.len() >= LATENCY_WINDOW {
                st.latencies.pop_front();
            }
            st.latencies.push_back(now - job.issued_at);
            if job.hedge {
                shared.res.stats.record_hedge_won();
            }
            st.attempts.remove(&job.idx);
            st.inflight.remove(&job.idx);
            st.done.insert(job.idx, bytes);
            shared.depth.set(st.done.len() as u64);
            shared.avail.notify_all();
            shared.space.notify_all();
        }
        Err(msg) => {
            if already_delivered || remaining > 0 {
                // A hedge copy is still racing (or already won) — this
                // failure costs nothing; let the survivor decide.
                shared.space.notify_all();
                return;
            }
            let (att, first) = *st.attempts.get(&job.idx).unwrap_or(&(job.attempt, job.issued_at));
            let policy = &shared.res.retry;
            let within = att < policy.attempts && (now - first) < policy.deadline;
            if within && is_transient(&msg) {
                shared.res.stats.record_retry();
                let not_before = now + policy.backoff_secs(att + 1, job.idx as u64);
                st.retry_queue.push((job.idx, not_before));
                shared.space.notify_all();
                return;
            }
            if att > 1 {
                shared.res.stats.record_give_up();
            }
            if st.error.is_none() {
                st.error =
                    Some(format!("part {} of {name}: {msg} (after {att} attempt(s))", job.idx));
            }
            shared.avail.notify_all();
            shared.space.notify_all();
        }
    }
}

fn worker_loop(
    shared: &Shared,
    store: &dyn Storage,
    name: &str,
    plan: PrefetchPlan,
    len: u64,
    tracer: &Tracer,
) {
    while let Some(job) = next_job(shared, plan) {
        let offset = job.idx as u64 * plan.part_size as u64;
        let want = (plan.part_size as u64).min(len - offset);
        // One span per ranged GET, sample = part index — first attempts
        // are Fetch (where fetch-stall time lives on a remote tier);
        // re-issues and hedge duplicates are Retry, so the Chrome trace
        // separates fault-recovery work from steady-state fetching.
        let span = tracer.start();
        let got = store.read_range(name, offset, want);
        let stage = if job.attempt > 1 || job.hedge { Stage::Retry } else { Stage::Fetch };
        tracer.record(stage, job.idx as u64, span);
        complete(shared, name, &job, want, got);
    }
}

/// Ordered `Read` over an object fetched by concurrent ranged reads.
pub struct PrefetchReader {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    current: Arc<[u8]>,
    pos: usize,
}

impl PrefetchReader {
    pub fn open(store: Arc<dyn Storage>, name: &str, plan: PrefetchPlan) -> Result<Self> {
        Self::open_traced(store, name, plan, Tracer::off())
    }

    /// [`open`](Self::open) with a span recorder: each worker's ranged
    /// GETs become `fetch` spans on that worker's own trace track.
    pub fn open_traced(
        store: Arc<dyn Storage>,
        name: &str,
        plan: PrefetchPlan,
        tracer: Tracer,
    ) -> Result<Self> {
        Self::open_resilient(store, name, plan, tracer, Resilience::none())
    }

    /// [`open_traced`](Self::open_traced) with a fault policy: failed
    /// parts re-issue with backoff through the window and stragglers are
    /// hedged (see the module docs).
    pub fn open_resilient(
        store: Arc<dyn Storage>,
        name: &str,
        plan: PrefetchPlan,
        tracer: Tracer,
        res: Resilience,
    ) -> Result<Self> {
        let len = store.len(name).with_context(|| format!("len of {name}"))?;
        let n_parts = (len as usize).div_ceil(plan.part_size);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                next_issue: 0,
                next_deliver: 0,
                n_parts,
                done: BTreeMap::new(),
                inflight: HashMap::new(),
                retry_queue: Vec::new(),
                attempts: HashMap::new(),
                latencies: VecDeque::new(),
                error: None,
                cancelled: false,
            }),
            avail: Condvar::new(),
            space: Condvar::new(),
            depth: Gauge::new(),
            t0: Instant::now(),
            res,
        });
        let n_workers = plan.conns.min(n_parts.max(1));
        let mut workers = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let shared_w = shared.clone();
            let store = store.clone();
            let name = name.to_string();
            let tracer = tracer.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("prefetch-{w}"))
                .spawn(move || {
                    worker_loop(&shared_w, store.as_ref(), &name, plan, len, &tracer)
                });
            match spawned {
                Ok(h) => workers.push(h),
                Err(e) => {
                    // A partial pool must not leak: cancel and reap the
                    // workers already running before surfacing the error.
                    // poison: see `next_job` — scheduler bookkeeping only.
                    shared.state.lock().unwrap().cancelled = true;
                    shared.space.notify_all();
                    shared.avail.notify_all();
                    for h in workers {
                        let _ = h.join();
                    }
                    return Err(e).with_context(|| format!("spawn prefetch worker {w}"));
                }
            }
        }
        Ok(PrefetchReader { shared, workers, current: Arc::from(&[][..]), pos: 0 })
    }

    /// Completed-parts queue depth gauge (level + high-water mark).
    pub fn queue_depth(&self) -> &Gauge {
        &self.shared.depth
    }

    /// Block until the next in-order part is ready; Ok(false) = EOF.
    fn next_part(&mut self) -> std::io::Result<bool> {
        // poison: see `next_job` — scheduler bookkeeping only.
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if let Some(bytes) = st.done.remove(&st.next_deliver) {
                st.next_deliver += 1;
                self.shared.depth.set(st.done.len() as u64);
                drop(st);
                self.shared.space.notify_all();
                self.current = bytes;
                self.pos = 0;
                return Ok(true);
            }
            if let Some(e) = &st.error {
                return Err(std::io::Error::other(e.clone()));
            }
            if st.next_deliver >= st.n_parts {
                return Ok(false); // clean EOF
            }
            // poison: see `next_job` — scheduler bookkeeping only.
            st = self.shared.avail.wait(st).unwrap();
        }
    }
}

impl Read for PrefetchReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        while self.pos >= self.current.len() {
            if !self.next_part()? {
                return Ok(0);
            }
        }
        let n = buf.len().min(self.current.len() - self.pos);
        buf[..n].copy_from_slice(&self.current[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

impl Drop for PrefetchReader {
    fn drop(&mut self) {
        {
            // poison: see `next_job` — scheduler bookkeeping only.
            let mut st = self.shared.state.lock().unwrap();
            st.cancelled = true;
        }
        self.shared.space.notify_all();
        self.shared.avail.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Fetch a whole object with `conns` concurrent ranged reads (unbounded
/// window, s3bfg's whole-file mode).  Returns the reassembled bytes.
pub fn fetch_parallel(
    store: Arc<dyn Storage>,
    name: &str,
    conns: usize,
    part_size: usize,
) -> Result<Vec<u8>> {
    let len = store.len(name)? as usize;
    let plan = PrefetchPlan { conns: conns.max(1), part_size: part_size.max(1), window_parts: usize::MAX / 2 };
    let mut r = PrefetchReader::open(store, name, plan)?;
    let mut out = Vec::with_capacity(len);
    r.read_to_end(&mut out)?;
    ensure!(out.len() == len, "fetched {} of {len} bytes of {name}", out.len());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::MemStore;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn blob(n: usize) -> Vec<u8> {
        // Position-dependent bytes so reordering bugs corrupt the data.
        (0..n).map(|i| (i % 251) as u8 ^ (i / 7919) as u8).collect()
    }

    fn mem(name: &str, data: Vec<u8>) -> Arc<dyn Storage> {
        let m = MemStore::new();
        m.write(name, data);
        Arc::new(m)
    }

    /// Zero-backoff bounded retry for tests (no wall-clock waits).
    fn fast_retry(attempts: u32) -> RetryPolicy {
        RetryPolicy {
            attempts,
            base_backoff: 0.0,
            max_backoff: 0.0,
            deadline: f64::INFINITY,
            seed: 1,
        }
    }

    #[test]
    fn reader_reassembles_in_order() {
        // Odd length so the tail part is short.
        let data = blob(1_000_003);
        let store = mem("b", data.clone());
        for (conns, part) in [(1, 4096), (4, 4096), (8, 65_536), (3, 1_000_003), (4, 2_000_000)] {
            let plan = PrefetchPlan::new(conns, part, 8 * part);
            let mut r = PrefetchReader::open(store.clone(), "b", plan).unwrap();
            let mut out = Vec::new();
            r.read_to_end(&mut out).unwrap();
            assert_eq!(out, data, "conns={conns} part={part}");
        }
    }

    #[test]
    fn empty_object_is_clean_eof() {
        let store = mem("e", Vec::new());
        let mut r = PrefetchReader::open(store, "e", PrefetchPlan::new(4, 1024, 8192)).unwrap();
        let mut out = Vec::new();
        r.read_to_end(&mut out).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn fetch_parallel_roundtrips() {
        let data = blob(777_777);
        let store = mem("b", data.clone());
        assert_eq!(fetch_parallel(store.clone(), "b", 8, 65_536).unwrap(), data);
        assert_eq!(fetch_parallel(store, "b", 1, 1 << 20).unwrap(), data);
    }

    #[test]
    fn window_bounds_readahead() {
        // 100 parts, window 4: after the reader consumes nothing, at most
        // window parts may complete.
        let data = blob(100 * 1024);
        let store = mem("b", data);
        let plan = PrefetchPlan { conns: 4, part_size: 1024, window_parts: 4 };
        let r = PrefetchReader::open(store, "b", plan).unwrap();
        // Give workers ample time (even descheduled on a loaded CI box)
        // to fill — and try to overfill — the window.
        std::thread::sleep(std::time::Duration::from_millis(150));
        let depth = r.queue_depth().peak();
        assert!(depth <= 4, "window overrun: {depth} parts buffered");
        assert!(depth >= 1, "nothing prefetched");
    }

    #[test]
    fn plan_window_covers_pool() {
        let p = PrefetchPlan::new(8, 1 << 20, 2 << 20);
        assert_eq!(p.window_parts, 8, "window must cover the connection pool");
        let p = PrefetchPlan::new(2, 1 << 20, 8 << 20);
        assert_eq!(p.window_parts, 8);
        assert!(PrefetchPlan::serial(4096).is_serial());
    }

    /// Storage that fails every read past a byte offset.
    struct FailAfter {
        inner: MemStore,
        limit: u64,
        reads: AtomicU64,
    }

    impl Storage for FailAfter {
        fn read(&self, name: &str) -> Result<Arc<[u8]>> {
            self.inner.read(name)
        }
        fn read_range(&self, name: &str, offset: u64, len: u64) -> Result<Arc<[u8]>> {
            self.reads.fetch_add(1, Ordering::Relaxed);
            anyhow::ensure!(offset < self.limit, "connection reset at offset {offset}");
            self.inner.read_range(name, offset, len)
        }
        fn len(&self, name: &str) -> Result<u64> {
            self.inner.len(name)
        }
        fn list(&self) -> Result<Vec<String>> {
            self.inner.list()
        }
        fn stats(&self) -> (u64, u64) {
            self.inner.stats()
        }
    }

    #[test]
    fn worker_error_surfaces_to_reader() {
        let inner = MemStore::new();
        inner.write("b", blob(64 * 1024));
        let store: Arc<dyn Storage> =
            Arc::new(FailAfter { inner, limit: 16 * 1024, reads: AtomicU64::new(0) });
        let mut r =
            PrefetchReader::open(store, "b", PrefetchPlan::new(4, 4096, 16 * 4096)).unwrap();
        let mut out = Vec::new();
        let err = r.read_to_end(&mut out).unwrap_err();
        assert!(err.to_string().contains("connection reset"), "{err}");
    }

    #[test]
    fn dropping_mid_stream_does_not_hang() {
        let data = blob(512 * 1024);
        let store = mem("b", data);
        let mut r =
            PrefetchReader::open(store, "b", PrefetchPlan::new(4, 4096, 8 * 4096)).unwrap();
        let mut buf = [0u8; 1000];
        let n = r.read(&mut buf).unwrap();
        assert!(n > 0);
        drop(r); // must cancel workers and join without deadlock
    }

    /// A traced reader turns every ranged GET into a `fetch` span on the
    /// issuing worker's track, tagged with the part index.
    #[test]
    fn traced_reader_records_fetch_spans() {
        use crate::metrics::trace::{Stage, Tracer};
        let data = blob(16 * 1024);
        let store = mem("b", data.clone());
        let tracer = Tracer::new(1.0);
        let plan = PrefetchPlan::new(2, 4096, 8 * 4096); // 4 parts
        let mut r =
            PrefetchReader::open_traced(store, "b", plan, tracer.clone()).unwrap();
        let mut out = Vec::new();
        r.read_to_end(&mut out).unwrap();
        assert_eq!(out, data);
        drop(r); // join the workers before draining their rings
        let dump = tracer.drain();
        let mut parts: Vec<u64> = dump
            .tracks
            .iter()
            .flat_map(|t| t.spans.iter())
            .filter(|s| s.stage == Stage::Fetch)
            .map(|s| s.sample)
            .collect();
        parts.sort();
        assert_eq!(parts, vec![0, 1, 2, 3], "one fetch span per part");
        assert!(
            dump.tracks.iter().any(|t| t.label.starts_with("prefetch-")),
            "spans must land on the prefetch workers' tracks"
        );
    }

    /// Storage whose first read of each range fails transiently; the
    /// retry (occurrence 2+) succeeds.
    struct FlakyFirst {
        inner: MemStore,
        seen: Mutex<std::collections::HashSet<u64>>,
        fails: AtomicU64,
    }

    impl Storage for FlakyFirst {
        fn read(&self, name: &str) -> Result<Arc<[u8]>> {
            self.inner.read(name)
        }
        fn read_range(&self, name: &str, offset: u64, len: u64) -> Result<Arc<[u8]>> {
            // poison: test-only set insert under the lock.
            if self.seen.lock().unwrap().insert(offset) {
                self.fails.fetch_add(1, Ordering::Relaxed);
                anyhow::bail!("transient glitch at offset {offset}");
            }
            self.inner.read_range(name, offset, len)
        }
        fn len(&self, name: &str) -> Result<u64> {
            self.inner.len(name)
        }
        fn list(&self) -> Result<Vec<String>> {
            self.inner.list()
        }
        fn stats(&self) -> (u64, u64) {
            self.inner.stats()
        }
    }

    /// The window-re-issue path: every part fails once, every part is
    /// re-issued and delivered, the stream stays byte-identical, and the
    /// retry counters see each re-attempt.
    #[test]
    fn transient_part_failures_reissue_and_complete() {
        let data = blob(64 * 1024); // 16 parts of 4 KiB
        let inner = MemStore::new();
        inner.write("b", data.clone());
        let store: Arc<dyn Storage> = Arc::new(FlakyFirst {
            inner,
            seen: Mutex::new(std::collections::HashSet::new()),
            fails: AtomicU64::new(0),
        });
        let stats = Arc::new(RetryStats::default());
        let res = Resilience::new(fast_retry(4), false, stats.clone());
        let mut r = PrefetchReader::open_resilient(
            store,
            "b",
            PrefetchPlan::new(4, 4096, 8 * 4096),
            Tracer::off(),
            res,
        )
        .unwrap();
        let mut out = Vec::new();
        r.read_to_end(&mut out).unwrap();
        assert_eq!(out, data, "retried stream must stay byte-identical");
        let (retries, _, give_ups) = stats.snapshot();
        assert_eq!(retries, 16, "each of the 16 parts fails once then recovers");
        assert_eq!(give_ups, 0);
    }

    /// Retried attempts show up as `retry` spans (first attempts stay
    /// `fetch`), so the Chrome trace separates recovery work.
    #[test]
    fn retried_attempts_record_retry_spans() {
        let data = blob(16 * 1024); // 4 parts
        let inner = MemStore::new();
        inner.write("b", data.clone());
        let store: Arc<dyn Storage> = Arc::new(FlakyFirst {
            inner,
            seen: Mutex::new(std::collections::HashSet::new()),
            fails: AtomicU64::new(0),
        });
        let tracer = Tracer::new(1.0);
        let res = Resilience::new(fast_retry(4), false, Arc::default());
        let mut r = PrefetchReader::open_resilient(
            store,
            "b",
            PrefetchPlan::new(2, 4096, 8 * 4096),
            tracer.clone(),
            res,
        )
        .unwrap();
        let mut out = Vec::new();
        r.read_to_end(&mut out).unwrap();
        assert_eq!(out, data);
        drop(r);
        let dump = tracer.drain();
        let spans: Vec<(Stage, u64)> = dump
            .tracks
            .iter()
            .flat_map(|t| t.spans.iter())
            .map(|s| (s.stage, s.sample))
            .collect();
        let fetches = spans.iter().filter(|(st, _)| *st == Stage::Fetch).count();
        let retries = spans.iter().filter(|(st, _)| *st == Stage::Retry).count();
        assert_eq!(fetches, 4, "one first-attempt fetch span per part");
        assert_eq!(retries, 4, "one retry span per re-issued part");
    }

    /// Exhausting the retry budget fails the stream with the part and
    /// attempt count — bounded, loud degradation instead of a hang.
    #[test]
    fn exhausted_retries_surface_part_and_attempts() {
        let inner = MemStore::new();
        inner.write("b", blob(16 * 1024));
        let store: Arc<dyn Storage> =
            Arc::new(FailAfter { inner, limit: 8 * 1024, reads: AtomicU64::new(0) });
        let stats = Arc::new(RetryStats::default());
        let res = Resilience::new(fast_retry(3), false, stats.clone());
        let mut r = PrefetchReader::open_resilient(
            store,
            "b",
            PrefetchPlan::new(2, 4096, 8 * 4096),
            Tracer::off(),
            res,
        )
        .unwrap();
        let mut out = Vec::new();
        let err = r.read_to_end(&mut out).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("after 3 attempt(s)"), "{msg}");
        assert!(msg.contains("connection reset"), "{msg}");
        let (retries, _, give_ups) = stats.snapshot();
        assert!(retries >= 2, "both failing parts should have retried: {retries}");
        assert!(give_ups >= 1, "exhaustion must be counted: {give_ups}");
    }

    /// Storage where one part's *first* read stalls for a long time;
    /// every other read (including the hedge duplicate of the stalled
    /// part) is instant.
    struct OneStraggler {
        inner: MemStore,
        slow_offset: u64,
        stalled: AtomicU64,
    }

    impl Storage for OneStraggler {
        fn read(&self, name: &str) -> Result<Arc<[u8]>> {
            self.inner.read(name)
        }
        fn read_range(&self, name: &str, offset: u64, len: u64) -> Result<Arc<[u8]>> {
            if offset == self.slow_offset
                && self.stalled.fetch_add(1, Ordering::Relaxed) == 0
            {
                std::thread::sleep(std::time::Duration::from_millis(300));
            }
            self.inner.read_range(name, offset, len)
        }
        fn len(&self, name: &str) -> Result<u64> {
            self.inner.len(name)
        }
        fn list(&self) -> Result<Vec<String>> {
            self.inner.list()
        }
        fn stats(&self) -> (u64, u64) {
            self.inner.stats()
        }
    }

    /// Hedging: the straggling part is duplicated once its age passes
    /// the trailing p95, the duplicate wins, the stream finishes *long*
    /// before the straggler's 300 ms stall, and the win is counted.
    #[test]
    fn hedged_duplicate_beats_straggler() {
        let data = blob(128 * 1024); // 32 parts of 4 KiB
        let inner = MemStore::new();
        inner.write("b", data.clone());
        // Stall a late part so the p95 estimate (8+ samples) is warm by
        // the time the straggler is issued.
        let store: Arc<dyn Storage> = Arc::new(OneStraggler {
            inner,
            slow_offset: 20 * 4096,
            stalled: AtomicU64::new(0),
        });
        let stats = Arc::new(RetryStats::default());
        let res = Resilience::new(fast_retry(1), true, stats.clone());
        let t0 = Instant::now();
        let mut r = PrefetchReader::open_resilient(
            store,
            "b",
            PrefetchPlan::new(4, 4096, 16 * 4096),
            Tracer::off(),
            res,
        )
        .unwrap();
        let mut out = Vec::new();
        r.read_to_end(&mut out).unwrap();
        assert_eq!(out, data, "hedged stream must stay byte-identical");
        assert!(
            t0.elapsed() < std::time::Duration::from_millis(250),
            "hedge must beat the 300ms straggler (took {:?})",
            t0.elapsed()
        );
        let (_, hedges_won, _) = stats.snapshot();
        assert!(hedges_won >= 1, "the duplicate's win must be counted");
        drop(r); // the stalled loser thread joins here without wedging
    }
}
