//! `dpp bench simd` — SIMD kernel microbench (CI smoke).
//!
//! Times each vectorized kernel against its scalar reference on hot,
//! cache-resident working sets and writes `BENCH_simd.json` for the CI
//! artifact.  Two layers of acceptance:
//!
//! * **Bit identity** (always, any ISA): every kernel's vector output is
//!   asserted `==` scalar *before* any timing — a speedup that changed a
//!   pixel is a bug, not a result.
//! * **Speedup gates** (AVX2 only): scaled IDCT and normalize must beat
//!   scalar by ≥2× and stay within a +10% band of the committed-baseline
//!   speedups below.  On SSE2-only or non-x86 hosts the timing rows are
//!   informational (scalar autovectorizes to SSE2-width code, so the
//!   honest headroom to gate on is AVX2's).
//!
//! The sim's `calib::SIMD_*_SPEEDUP` constants are calibrated from these
//! rows (see DESIGN.md "SIMD kernels").

use crate::bench::Bencher;
use crate::codec::dct::{dequant_idct_block_level, dequant_idct_block_scaled_level};
use crate::codec::{qtable_for_quality, EntropyReader, EntropyWriter};
use crate::ops::{self, AugParams, AugScratch};
use crate::simd::{detect, SimdLevel};
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{ensure, Result};
use std::path::Path;

/// Committed-baseline AVX2-over-scalar speedups (dev-box measurement);
/// the regression gate allows a +10% band below each before failing.
/// `2.2 / 1.10 = 2.0`, so the band floor coincides with the ISSUE's
/// hard ≥2× acceptance line.
const BASELINE_IDCT_SPEEDUP: f64 = 2.2;
const BASELINE_NORM_SPEEDUP: f64 = 2.2;
const BASELINE_BAND: f64 = 1.10;

/// One benched kernel: scalar vs the best detected tier.
pub struct SimdBenchRow {
    pub name: &'static str,
    /// "block" or "pixel" — what `scalar_ns`/`simd_ns` are per.
    pub unit: &'static str,
    pub scalar_ns: f64,
    pub simd_ns: f64,
    pub speedup: f64,
    /// Whether the AVX2 regression gate applies to this row.
    pub gated: bool,
}

impl SimdBenchRow {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name)),
            ("unit", Json::str(self.unit)),
            ("scalar_ns", Json::num(self.scalar_ns)),
            ("simd_ns", Json::num(self.simd_ns)),
            ("speedup", Json::num(self.speedup)),
            ("gated", Json::Bool(self.gated)),
        ])
    }
}

/// Dense quantized coefficient blocks (every AC nonzero, so the
/// DC-only fast path never fires and both tiers do full work) plus the
/// matching qtable.
fn gen_dense_blocks(n: usize, seed: u64) -> (Vec<[f32; 64]>, [f32; 64]) {
    let mut rng = Rng::new(seed);
    let q = qtable_for_quality(85);
    let blocks = (0..n)
        .map(|_| {
            let mut b = [0f32; 64];
            for v in b.iter_mut() {
                let mag = 1 + (rng.next_u32() % 50) as i32;
                let signed = if rng.next_u32() & 1 == 0 { mag } else { -mag };
                *v = signed as f32;
            }
            b
        })
        .collect();
    (blocks, q)
}

/// A realistic entropy stream: sparse blocks with runs and multi-byte
/// varint coefficients, plus the decoded reference values.
fn gen_entropy_stream(nblocks: usize, seed: u64) -> (Vec<u8>, Vec<[i32; 64]>) {
    let mut rng = Rng::new(seed);
    let mut buf = Vec::new();
    let mut writer = EntropyWriter::new(&mut buf);
    let mut blocks = Vec::with_capacity(nblocks);
    for _ in 0..nblocks {
        let mut b = [0i32; 64];
        b[0] = (rng.next_u32() % 4000) as i32 - 2000;
        // ~12 nonzero ACs per block, occasionally large (multi-byte).
        for _ in 0..12 {
            let zi = 1 + (rng.next_u32() % 63) as usize;
            let mag = 1 + (rng.next_u32() % 300_000) as i32;
            b[zi] = if rng.next_u32() & 1 == 0 { mag } else { -mag };
        }
        writer.write_block(&b).expect("write_block");
        blocks.push(b);
    }
    writer.finish().expect("finish");
    (buf, blocks)
}

fn decode_all(buf: &[u8], nblocks: usize, fast: bool) -> Vec<[i32; 64]> {
    let mut reader = EntropyReader::with_table_decode(buf, fast);
    let mut out = Vec::with_capacity(nblocks);
    let mut q = [0i32; 64];
    for _ in 0..nblocks {
        reader.read_block(&mut q).expect("read_block");
        out.push(q);
    }
    out
}

/// Run the microbench; optionally write `BENCH_simd.json` to `out`.
pub fn run(out: Option<&Path>) -> Result<Json> {
    run_with(out, 200, true)
}

/// [`run`] with an explicit per-kernel timing budget and gate switch —
/// the unit test uses a small budget and no timing gates (timing under
/// test-harness contention flakes; bit identity is asserted either way).
pub fn run_with(out: Option<&Path>, budget_ms: u64, gate: bool) -> Result<Json> {
    let best = detect();
    let b = Bencher::with_budget(budget_ms);
    let mut rows = Vec::new();

    // --- scaled IDCT, 8-point (full-resolution kernel), dense blocks ---
    let nblocks = 64usize;
    let (blocks, q) = gen_dense_blocks(nblocks, 11);
    let mut got = [0f32; 64];
    let mut want = [0f32; 64];
    for blk in &blocks {
        dequant_idct_block_level(blk, &q, &mut want, SimdLevel::Scalar);
        dequant_idct_block_level(blk, &q, &mut got, best);
        ensure!(got == want, "idct8 not bit-identical at {:?}", best);
    }
    let time_idct8 = |level: SimdLevel| {
        b.run(&format!("idct8:{}", level.name()), || {
            let mut pix = [0f32; 64];
            for blk in &blocks {
                dequant_idct_block_level(blk, &q, &mut pix, level);
            }
            pix
        })
        .mean_ns
            / nblocks as f64
    };
    let (s, v) = (time_idct8(SimdLevel::Scalar), time_idct8(best));
    rows.push(SimdBenchRow {
        name: "idct8",
        unit: "block",
        scalar_ns: s,
        simd_ns: v,
        speedup: s / v,
        gated: true,
    });

    // --- scaled IDCT, 4-point (1/2-scale kernel) ---
    let mut got4 = [0f32; 16];
    let mut want4 = [0f32; 16];
    for blk in &blocks {
        dequant_idct_block_scaled_level(blk, &q, 1, &mut want4, SimdLevel::Scalar);
        dequant_idct_block_scaled_level(blk, &q, 1, &mut got4, best);
        ensure!(got4 == want4, "idct4 not bit-identical at {:?}", best);
    }
    let time_idct4 = |level: SimdLevel| {
        b.run(&format!("idct4:{}", level.name()), || {
            let mut pix = [0f32; 16];
            for blk in &blocks {
                dequant_idct_block_scaled_level(blk, &q, 1, &mut pix, level);
            }
            pix
        })
        .mean_ns
            / nblocks as f64
    };
    let (s, v) = (time_idct4(SimdLevel::Scalar), time_idct4(best));
    rows.push(SimdBenchRow {
        name: "idct4",
        unit: "block",
        scalar_ns: s,
        simd_ns: v,
        speedup: s / v,
        gated: false,
    });

    // --- normalize (L1-resident 3×32×32 tile) ---
    let hw = 32 * 32;
    let mut rng = Rng::new(12);
    let src: Vec<f32> = (0..3 * hw).map(|_| (rng.next_u32() % 256) as f32).collect();
    let mut dst_s = vec![0f32; 3 * hw];
    let mut dst_v = vec![0f32; 3 * hw];
    ops::normalize_into_level(&src, 3, hw, &mut dst_s, SimdLevel::Scalar);
    ops::normalize_into_level(&src, 3, hw, &mut dst_v, best);
    ensure!(dst_s == dst_v, "normalize not bit-identical at {:?}", best);
    let time_norm = |level: SimdLevel| {
        let mut dst = vec![0f32; 3 * hw];
        b.run(&format!("normalize:{}", level.name()), || {
            ops::normalize_into_level(&src, 3, hw, &mut dst, level);
            dst[0]
        })
        .mean_ns
            / (3 * hw) as f64
    };
    let (s, v) = (time_norm(SimdLevel::Scalar), time_norm(best));
    rows.push(SimdBenchRow {
        name: "normalize",
        unit: "pixel",
        scalar_ns: s,
        simd_ns: v,
        speedup: s / v,
        gated: true,
    });

    // --- fused resize-bilerp+normalize (48×48 crop of 64×64 → 56×56) ---
    let (c, h, w, oh, ow) = (3usize, 64usize, 64usize, 56usize, 56usize);
    let img: Vec<f32> = (0..c * h * w).map(|_| (rng.next_u32() % 256) as f32).collect();
    let p = AugParams { y0: 4, x0: 4, crop_h: 48, crop_w: 48, flip: false };
    let mut aug_s = vec![0f32; c * oh * ow];
    let mut aug_v = vec![0f32; c * oh * ow];
    let mut scratch = AugScratch::new();
    ops::augment_fused_view_into_level(
        &img, c, h, w, (0, 0, h, w), &p, oh, ow, &mut scratch, &mut aug_s,
        SimdLevel::Scalar,
    );
    ops::augment_fused_view_into_level(
        &img, c, h, w, (0, 0, h, w), &p, oh, ow, &mut scratch, &mut aug_v, best,
    );
    ensure!(aug_s == aug_v, "bilerp+normalize not bit-identical at {:?}", best);
    let time_aug = |level: SimdLevel| {
        let mut o = vec![0f32; c * oh * ow];
        let mut sc = AugScratch::new();
        b.run(&format!("bilerp-norm:{}", level.name()), || {
            ops::augment_fused_view_into_level(
                &img, c, h, w, (0, 0, h, w), &p, oh, ow, &mut sc, &mut o, level,
            );
            o[0]
        })
        .mean_ns
            / (c * oh * ow) as f64
    };
    let (s, v) = (time_aug(SimdLevel::Scalar), time_aug(best));
    rows.push(SimdBenchRow {
        name: "bilerp-norm",
        unit: "pixel",
        scalar_ns: s,
        simd_ns: v,
        speedup: s / v,
        gated: false,
    });

    // --- entropy decode: byte-at-a-time reference vs table+window ---
    let nstream = 256usize;
    let (stream, blocks_ref) = gen_entropy_stream(nstream, 13);
    ensure!(
        decode_all(&stream, nstream, false) == blocks_ref
            && decode_all(&stream, nstream, true) == blocks_ref,
        "entropy fast path not identical to slow path"
    );
    let time_entropy = |fast: bool| {
        b.run(if fast { "entropy:table" } else { "entropy:slow" }, || {
            let mut reader = EntropyReader::with_table_decode(&stream, fast);
            let mut q = [0i32; 64];
            for _ in 0..nstream {
                reader.read_block(&mut q).unwrap();
            }
            q[0]
        })
        .mean_ns
            / nstream as f64
    };
    let (s, v) = (time_entropy(false), time_entropy(true));
    rows.push(SimdBenchRow {
        name: "entropy",
        unit: "block",
        scalar_ns: s,
        simd_ns: v,
        speedup: s / v,
        gated: false,
    });

    println!("== simd microbench (best detected tier: {}) ==", best.name());
    println!(
        "{:<14} {:>7} {:>14} {:>14} {:>9} {:>6}",
        "kernel", "unit", "scalar ns/u", "simd ns/u", "speedup", "gated"
    );
    for r in &rows {
        println!(
            "{:<14} {:>7} {:>14.1} {:>14.1} {:>8.2}x {:>6}",
            r.name, r.unit, r.scalar_ns, r.simd_ns, r.speedup, r.gated
        );
    }

    // Regression gates: AVX2 only — that is where the committed baseline
    // was measured, and scalar autovectorizes to SSE2 width anyway.
    if gate && best == SimdLevel::Avx2 {
        for (name, baseline) in
            [("idct8", BASELINE_IDCT_SPEEDUP), ("normalize", BASELINE_NORM_SPEEDUP)]
        {
            let row = rows.iter().find(|r| r.name == name).expect("row exists");
            let floor = (baseline / BASELINE_BAND).max(2.0);
            ensure!(
                row.speedup >= floor,
                "{name} speedup {:.2}x regressed below {:.2}x \
                 (committed baseline {:.1}x, +10% band)",
                row.speedup,
                floor,
                baseline
            );
        }
    } else if gate {
        println!("  (no AVX2 on this host — speedup gates skipped, identity still asserted)");
    }

    let json = Json::obj(vec![
        ("bench", Json::str("simd")),
        ("detected", Json::str(best.name())),
        ("baseline_idct_speedup", Json::num(BASELINE_IDCT_SPEEDUP)),
        ("baseline_norm_speedup", Json::num(BASELINE_NORM_SPEEDUP)),
        ("baseline_band", Json::num(BASELINE_BAND)),
        ("rows", Json::arr(rows.iter().map(|r| r.to_json()))),
    ]);
    if let Some(path) = out {
        std::fs::write(path, json.pretty())?;
        println!("  wrote {}", path.display());
    }
    Ok(json)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The bench's bit-identity layer and JSON shape, with a tiny budget
    /// and the timing gates off (wall-clock ratios under test-harness
    /// contention are not a signal; CI's bench smoke step runs them).
    #[test]
    fn bench_asserts_identity_and_reports_all_kernels() {
        let json = run_with(None, 20, false).unwrap();
        assert_eq!(json.req("bench").as_str(), Some("simd"));
        assert_eq!(json.req("detected").as_str(), Some(detect().name()));
        let rows = json.req("rows").as_arr().expect("rows array");
        let names: Vec<_> =
            rows.iter().map(|r| r.req("name").as_str().unwrap().to_string()).collect();
        for want in ["idct8", "idct4", "normalize", "bilerp-norm", "entropy"] {
            assert!(names.iter().any(|n| n == want), "missing row {want}");
        }
        for r in rows {
            assert!(r.req("scalar_ns").as_f64().unwrap() > 0.0);
            assert!(r.req("simd_ns").as_f64().unwrap() > 0.0);
            assert!(r.req("speedup").as_f64().unwrap() > 0.0);
        }
    }

    /// The generators feed both decode paths identical, nontrivial data
    /// (dense IDCT blocks; entropy streams with runs + multi-byte
    /// varints) — miri-friendly: no timing, no intrinsics.
    #[test]
    fn generators_produce_identical_fast_and_slow_decodes() {
        let n = if cfg!(miri) { 4 } else { 64 };
        let (stream, blocks) = gen_entropy_stream(n, 99);
        assert_eq!(decode_all(&stream, n, false), blocks);
        assert_eq!(decode_all(&stream, n, true), blocks);
        let (dense, q) = gen_dense_blocks(8, 3);
        let mut a = [0f32; 64];
        let mut b = [0f32; 64];
        for blk in &dense {
            assert!(blk.iter().all(|&v| v != 0.0), "dense blocks must defeat DC fast path");
            dequant_idct_block_level(blk, &q, &mut a, SimdLevel::Scalar);
            dequant_idct_block_level(blk, &q, &mut b, detect());
            assert_eq!(a, b);
        }
    }
}
