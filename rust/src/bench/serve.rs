//! `dpp bench serve` — multi-tenant churn smoke (CI gate).
//!
//! A four-job scenario runs through the *real* serve engine — registry
//! quotas, DRR scheduling, admission control, per-job quarantine — with
//! mid-run churn and seeded faults, twice (quotas on, then off).  Every
//! gate is counter-based and deterministic (virtual rounds, seeded
//! draws), so CI asserts behavior, never a wall clock:
//!
//! * **isolation** — with quotas on, a 16 MiB aggressor joining mid-run
//!   cannot evict the small victim's working set: the victim keeps its
//!   steady-state hit rate; with quotas off the same churn collapses it
//!   (the A/B that justifies the registry);
//! * **admission** — the over-demand glutton is rejected by the cost
//!   model (admitting it would push the aggressor below the goodput
//!   floor); the well-behaved tenants are not;
//! * **failure domains** — the faulty job exhausts its per-epoch skip
//!   budget and fails *alone*; the other tenants complete every epoch
//!   with clean fault counters.
//!
//! Writes the per-job rows as JSON (`BENCH_serve.json`) for the CI
//! artifact.

use crate::pipeline::prep_cache::PrepCachePolicy;
use crate::service::engine::{self, JobSpec, ServeReport, ServeScenario};
use crate::util::json::Json;
use anyhow::{ensure, Result};
use std::path::Path;

/// The churn scenario: a cache-resident victim, a mid-run flood
/// aggressor, a doomed faulty job, and a glutton admission must refuse.
fn scenario(quotas: bool) -> ServeScenario {
    let job = |name: &str| JobSpec { name: name.into(), ..JobSpec::default() };
    ServeScenario {
        jobs: vec![
            // 384 KiB working set: fits every quota split this scenario
            // produces, so with isolation on it should never miss after
            // epoch one.
            JobSpec { dataset_items: 48, demand: 16, epochs: 8, ..job("victim") },
            // 16 MiB >> the 2 MiB cache: pure flood traffic.
            JobSpec {
                dataset_items: 2048,
                demand: 128,
                epochs: 2,
                join_round: 4,
                ..job("aggressor")
            },
            // Faults at 90% with no retries and a zero skip budget: the
            // first unrecovered sample fails the job.
            JobSpec {
                dataset_items: 64,
                demand: 8,
                epochs: 4,
                fault_rate: 0.9,
                ..job("faulty")
            },
            // Asks for more than the pool can give without starving the
            // aggressor below the floor: admission must say no.
            JobSpec {
                dataset_items: 8192,
                demand: 2000,
                epochs: 1,
                join_round: 6,
                ..job("glutton")
            },
        ],
        seed: 42,
        cache_bytes: 2 << 20,
        quotas,
        goodput_floor: 0.6,
        workers_min: 1,
        workers_max: 32,
        policy: PrepCachePolicy::Lru,
    }
}

fn job_json(r: &ServeReport) -> Json {
    Json::arr(r.jobs.iter().map(|j| j.to_json()))
}

/// Run the churn A/B; optionally write `BENCH_serve.json` to `out`.
pub fn run_bench(out: Option<&Path>) -> Result<Json> {
    println!("== serve churn smoke (4 jobs, 2 MiB shared cache, seed 42) ==");
    let on = engine::run(&scenario(true))?;
    let off = engine::run(&scenario(false))?;
    for (label, r) in [("quotas=on", &on), ("quotas=off", &off)] {
        println!("-- {label} --");
        r.print_summary();
    }

    let v_on = on.section("victim").unwrap();
    let v_off = off.section("victim").unwrap();
    let a_on = on.section("aggressor").unwrap();
    let f_on = on.section("faulty").unwrap();

    // Gate 1: isolation — quotas keep the victim's steady-state hit
    // rate through the aggressor's flood; sharing one pool loses it.
    ensure!(
        v_on.status == "done" && v_on.epochs_done == 8,
        "victim must finish all epochs under quotas, got {:?}",
        v_on.status
    );
    ensure!(
        v_on.hit_rate >= 0.9,
        "quotas on: victim steady-state hit rate collapsed to {:.3}",
        v_on.hit_rate
    );
    ensure!(
        v_off.hit_rate < 0.5 * v_on.hit_rate,
        "quotas off should demonstrate the collapse ({:.3} vs {:.3})",
        v_off.hit_rate,
        v_on.hit_rate
    );

    // Gate 2: admission — the glutton is rejected up front; the
    // well-behaved tenants are not.
    ensure!(
        on.rejected == vec!["glutton".to_string()],
        "admission must reject exactly the glutton, got {:?}",
        on.rejected
    );
    ensure!(
        a_on.status == "done" && a_on.epochs_done == 2,
        "aggressor was admitted and must complete, got {:?}",
        a_on.status
    );

    // Gate 3: failure isolation — the faulty job dies on its own skip
    // budget; nobody else sees a fault.
    ensure!(
        f_on.status.starts_with("failed"),
        "faulty job must fail its skip budget, got {:?}",
        f_on.status
    );
    ensure!(f_on.faults_injected > 0, "faulty job saw no injected faults — seed drift?");
    ensure!(
        v_on.faults_injected == 0 && a_on.faults_injected == 0,
        "fault counters must stay per-job"
    );

    // Determinism: the same scenario replays the same report.
    let replay = engine::run(&scenario(true))?;
    ensure!(
        replay.rounds == on.rounds
            && replay.section("victim").unwrap().hit_rate == v_on.hit_rate,
        "serve engine must be deterministic per seed"
    );

    let json = Json::obj(vec![
        ("bench", Json::str("serve")),
        ("seed", Json::num(42.0)),
        ("rounds_quotas_on", Json::num(on.rounds as f64)),
        ("rounds_quotas_off", Json::num(off.rounds as f64)),
        ("victim_hit_rate_quotas_on", Json::num(v_on.hit_rate)),
        ("victim_hit_rate_quotas_off", Json::num(v_off.hit_rate)),
        ("rejected", Json::arr(on.rejected.iter().map(|s| Json::str(s)))),
        ("jobs_quotas_on", job_json(&on)),
        ("jobs_quotas_off", job_json(&off)),
    ]);
    if let Some(path) = out {
        std::fs::write(path, json.pretty())?;
        println!("  wrote {}", path.display());
    }
    Ok(json)
}

/// The `dpp bench serve` entry point (mirrors the other bench targets).
pub fn run(out: Option<&Path>) -> Result<Json> {
    run_bench(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_bench_gates_hold_without_io() {
        // The same gates `dpp bench serve` enforces, minus the file.
        let json = run_bench(None).unwrap();
        let dump = json.dump();
        assert!(dump.contains("\"bench\":\"serve\""));
        for name in ["victim", "aggressor", "faulty", "glutton"] {
            assert!(dump.contains(name), "{name} row missing");
        }
    }
}
