//! Micro-benchmark harness: warms up, auto-picks an iteration count for a
//! target measurement budget, reports mean/std/p50/p95 and a derived rate.

use crate::util::stats::{mean, percentile};
use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.mean_ns / 1e9
    }

    pub fn print(&self) {
        println!(
            "  {:<44} {:>12}  ±{:>10}  p95 {:>12}  ({} iters)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.std_ns),
            fmt_ns(self.p95_ns),
            self.iters
        );
    }

    /// Print with a throughput line, `units_per_iter` units per iteration.
    pub fn print_rate(&self, units_per_iter: f64, unit: &str) {
        let rate = units_per_iter / self.mean_secs();
        println!(
            "  {:<44} {:>12}  {:>16}",
            self.name,
            fmt_ns(self.mean_ns),
            crate::util::human_rate(rate, unit)
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

pub struct Bencher {
    budget: Duration,
    warmup: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { budget: Duration::from_millis(700), warmup: Duration::from_millis(150) }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_budget(budget_ms: u64) -> Self {
        Bencher { budget: Duration::from_millis(budget_ms), warmup: Duration::from_millis(budget_ms / 5) }
    }

    /// Measure `f`, returning per-iteration stats.
    pub fn run<R>(&self, name: &str, mut f: impl FnMut() -> R) -> BenchResult {
        // Warmup + calibrate single-iteration cost.
        let w0 = Instant::now();
        let mut warm_iters = 0u64;
        while w0.elapsed() < self.warmup || warm_iters < 3 {
            std::hint::black_box(f());
            warm_iters += 1;
        }
        let per_iter = w0.elapsed().as_secs_f64() / warm_iters as f64;
        let samples = 30usize;
        let iters_per_sample =
            ((self.budget.as_secs_f64() / samples as f64 / per_iter.max(1e-9)).ceil() as usize)
                .max(1);

        let mut times = Vec::with_capacity(samples);
        for _ in 0..samples {
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                std::hint::black_box(f());
            }
            times.push(t.elapsed().as_nanos() as f64 / iters_per_sample as f64);
        }
        let m = mean(&times);
        let var = times.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / times.len() as f64;
        let mut sorted = times.clone();
        BenchResult {
            name: name.to_string(),
            iters: samples * iters_per_sample,
            mean_ns: m,
            std_ns: var.sqrt(),
            p50_ns: percentile(&mut sorted, 50.0),
            p95_ns: percentile(&mut sorted, 95.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_a_known_sleep() {
        let b = Bencher::with_budget(120);
        let r = b.run("sleep-2ms", || std::thread::sleep(Duration::from_millis(2)));
        assert!(r.mean_ns > 1.8e6 && r.mean_ns < 6e6, "{}", r.mean_ns);
        assert!(r.iters >= 30);
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1.5e3), "1.500 µs");
        assert_eq!(fmt_ns(2.5e6), "2.500 ms");
        assert_eq!(fmt_ns(3.2e9), "3.200 s");
    }
}
