//! `dpp bench decode` — counter-based decode microbench (CI smoke).
//!
//! Compares the full decoder against the fused ROI / fractional-scale
//! paths on a representative RandomResizedCrop geometry and reports
//! **blocks dequant+IDCT'd per image** (deterministic — what CI asserts)
//! plus ns/image (informational; never asserted, so no wall-clock
//! flakiness).  Writes the rows as JSON (`BENCH_decode.json`) for the CI
//! artifact.

use crate::bench::Bencher;
use crate::codec::{self, DecodePlan};
use crate::util::json::Json;
use anyhow::{ensure, Result};
use std::path::Path;

/// One benched decode path.
pub struct DecodeBenchRow {
    pub name: &'static str,
    pub blocks_idct: u64,
    pub blocks_skipped: u64,
    /// IDCT blocks per fractional scale (`[k]` = the `1/2^k` kernel) —
    /// sums to `blocks_idct`, so a SIMD speedup measured per kernel in
    /// `dpp bench simd` can be attributed without guessing the mix.
    pub blocks_by_scale: [u64; 4],
    pub scale: usize,
    pub ns_per_image: f64,
}

impl DecodeBenchRow {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name)),
            ("blocks_idct", Json::num(self.blocks_idct as f64)),
            ("blocks_skipped", Json::num(self.blocks_skipped as f64)),
            (
                "blocks_by_scale",
                Json::arr(self.blocks_by_scale.iter().map(|&n| Json::num(n as f64))),
            ),
            ("scale", Json::num(self.scale as f64)),
            ("ns_per_image", Json::num(self.ns_per_image)),
        ])
    }
}

/// Run the microbench; optionally write `BENCH_decode.json` to `out`.
///
/// The representative geometry is the ISSUE's acceptance case: a 64×64
/// image, a ~0.4-area (40×40) crop, out_hw = 56.  The counter-based
/// acceptance — fused ROI must dequant+IDCT at most half the blocks of
/// the full decode — is enforced here and in `tests/fused_decode.rs`.
pub fn run(out: Option<&Path>) -> Result<Json> {
    let img = crate::dataset::gen_image(&mut crate::util::rng::Rng::new(7), 5, 3, 64, 64);
    let bytes = codec::encode(&img, 85)?;
    let b = Bencher::with_budget(250);

    // Full decode: every block pays dequant+IDCT.
    let full_blocks = 3 * 8 * 8u64;
    let full = b.run("decode:full", || codec::decode_cpu(&bytes).unwrap());

    // Fused ROI at full scale: the representative RandomResizedCrop.
    let roi_plan = DecodePlan::new(3, 64, 64, (0, 0, 40, 40), 56, 0);
    let (_, roi_stats) = codec::decode_cpu_planned(&bytes, &roi_plan)?;
    let roi = b.run("decode:fused-roi", || codec::decode_cpu_planned(&bytes, &roi_plan).unwrap());

    // Fused ROI + 1/2 scale (a 32×32 crop feeding a 16×16 output).
    let scaled_plan = DecodePlan::new(3, 64, 64, (0, 0, 32, 32), 16, 3);
    let (_, scaled_stats) = codec::decode_cpu_planned(&bytes, &scaled_plan)?;
    let scaled = b.run("decode:fused-roi+scale", || {
        codec::decode_cpu_planned(&bytes, &scaled_plan).unwrap()
    });

    let rows = [
        DecodeBenchRow {
            name: "full",
            blocks_idct: full_blocks,
            blocks_skipped: 0,
            blocks_by_scale: [full_blocks, 0, 0, 0],
            scale: 1,
            ns_per_image: full.mean_ns,
        },
        DecodeBenchRow {
            name: "fused-roi",
            blocks_idct: roi_stats.blocks_idct,
            blocks_skipped: roi_stats.blocks_skipped,
            blocks_by_scale: roi_stats.blocks_by_scale,
            scale: 1,
            ns_per_image: roi.mean_ns,
        },
        DecodeBenchRow {
            name: "fused-roi+scale",
            blocks_idct: scaled_stats.blocks_idct,
            blocks_skipped: scaled_stats.blocks_skipped,
            blocks_by_scale: scaled_stats.blocks_by_scale,
            scale: 1 << scaled_plan.scale_log2,
            ns_per_image: scaled.mean_ns,
        },
    ];

    println!("== decode microbench (64x64 q85, crop 40x40 -> out 56) ==");
    println!(
        "{:<18} {:>12} {:>14} {:>20} {:>7} {:>14}",
        "path", "blocks idct", "blocks skipped", "by scale 8/4/2/1", "scale", "ns/image"
    );
    for r in &rows {
        let by = r.blocks_by_scale;
        println!(
            "{:<18} {:>12} {:>14} {:>20} {:>6}x {:>14.0}",
            r.name,
            r.blocks_idct,
            r.blocks_skipped,
            format!("{}/{}/{}/{}", by[0], by[1], by[2], by[3]),
            r.scale,
            r.ns_per_image
        );
    }
    let ratio = full_blocks as f64 / roi_stats.blocks_idct.max(1) as f64;
    println!("  fused ROI does {ratio:.2}x fewer dequant+IDCT block ops per image");
    // The acceptance gate is counter-based, so CI cannot flake on timing.
    ensure!(
        roi_stats.blocks_idct * 2 <= full_blocks,
        "fused ROI must halve block ops: {} vs {full_blocks}",
        roi_stats.blocks_idct
    );
    ensure!(
        roi_stats.blocks_idct + roi_stats.blocks_skipped == full_blocks,
        "fused ROI must account for every block"
    );

    let json = Json::obj(vec![
        ("bench", Json::str("decode")),
        ("image", Json::str("64x64x3 q85")),
        ("crop", Json::str("40x40@(0,0) out 56")),
        ("roi_block_ratio", Json::num(ratio)),
        ("rows", Json::arr(rows.iter().map(|r| r.to_json()))),
    ]);
    if let Some(path) = out {
        std::fs::write(path, json.pretty())?;
        println!("  wrote {}", path.display());
    }
    Ok(json)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn microbench_counters_hit_the_acceptance_ratio() {
        // Counter math only, asserted straight off the decode stats so
        // the test stays instant (the timed harness runs in CI's bench
        // smoke step, not here): crop 40x40 at the origin covers 5x5 of
        // the 8x8 block grid per channel.
        let img =
            crate::dataset::gen_image(&mut crate::util::rng::Rng::new(7), 5, 3, 64, 64);
        let bytes = codec::encode(&img, 85).unwrap();
        let roi_plan = DecodePlan::new(3, 64, 64, (0, 0, 40, 40), 56, 0);
        let (_, roi) = codec::decode_cpu_planned(&bytes, &roi_plan).unwrap();
        let full_blocks = 3 * 8 * 8u64;
        assert_eq!(roi.blocks_idct, 3 * 25);
        assert!(roi.blocks_idct * 2 <= full_blocks, "must halve block ops");
        assert_eq!(roi.blocks_idct + roi.blocks_skipped, full_blocks);
        // Per-scale attribution: the unscaled ROI is all 1/1-kernel
        // blocks; the 1/2-scale plan books all of its under scale 1.
        assert_eq!(roi.blocks_by_scale, [3 * 25, 0, 0, 0]);
        let scaled_plan = DecodePlan::new(3, 64, 64, (0, 0, 32, 32), 16, 3);
        assert_eq!(1 << scaled_plan.scale_log2, 2);
        let (_, scaled) = codec::decode_cpu_planned(&bytes, &scaled_plan).unwrap();
        assert_eq!(scaled.blocks_by_scale, [0, 3 * 16, 0, 0]);
        assert_eq!(scaled.blocks_by_scale.iter().sum::<u64>(), scaled.blocks_idct);
    }
}
