//! `dpp bench trace-overhead` — span-tracing cost microbench (CI smoke).
//!
//! Runs the cpu-placement stage chain over a small corpus twice — once
//! with `Tracer::off()`, once with a full-rate tracer (`--trace-sample-rate
//! 1.0`, the worst case: every span recorded) — and reports ns/sample
//! for both paths.
//!
//! Gates (enforced here and by the CI smoke step):
//! * deterministic span accounting: full-rate tracing keeps exactly one
//!   decode + one augment span per sample; a strided tracer keeps
//!   `ceil(n/stride)` per stage; a wrapped ring reports every
//!   overwritten span in `TraceDump::dropped`;
//! * the traced path stays within [`TRACE_OVERHEAD_LIMIT_PCT`] of the
//!   untraced path (min-over-rounds on both sides, so scheduler noise
//!   must hit every round to flake the gate) — the ISSUE's "tracing is
//!   cheap enough to leave on" contract.
//!
//! The in-crate tests run the deterministic gates only: timing gates
//! live in the CI smoke step (`dpp bench trace-overhead`), where the
//! process is quiet (repo precedent from `bench/alloc.rs`).

use crate::config::Placement;
use crate::metrics::trace::{Stage, Tracer};
use crate::ops;
use crate::pipeline::StageCtx;
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{ensure, Result};
use std::path::Path;
use std::time::Instant;

/// Committed ceiling on the traced path's slowdown over the untraced
/// path, in percent.  A full-rate span is one clock read plus four
/// relaxed stores against a ~10 µs decode, so 3% leaves real headroom —
/// the gate exists to fail loudly if a lock or allocation sneaks onto
/// the record path.
pub const TRACE_OVERHEAD_LIMIT_PCT: f64 = 3.0;

/// Corpus/batch geometry, matching `dpp bench alloc`/`decode`.
const BATCH: usize = 32;
const IMG_HW: usize = 64;
const OUT_HW: usize = 56;

fn corpus() -> (Vec<Vec<u8>>, Vec<ops::AugParams>) {
    let enc: Vec<Vec<u8>> = (0..BATCH)
        .map(|i| {
            let img = crate::dataset::gen_image(
                &mut Rng::new(i as u64 + 1),
                (i % 5) as u16,
                3,
                IMG_HW,
                IMG_HW,
            );
            crate::codec::encode(&img, 85).unwrap()
        })
        .collect();
    let mut rng = Rng::new(0x7ACE);
    let augs: Vec<ops::AugParams> = (0..BATCH)
        .map(|_| ops::sample_aug_params(&mut rng, IMG_HW as u32, IMG_HW as u32))
        .collect();
    (enc, augs)
}

/// Minimum ns/sample over `rounds` passes of `batches` corpus sweeps
/// through `ctx` (one warm-up pass first).
fn measure(ctx: &StageCtx, enc: &[Vec<u8>], augs: &[ops::AugParams], rounds: usize, batches: usize) -> f64 {
    let sweep = || {
        for _ in 0..batches {
            for (i, bytes) in enc.iter().enumerate() {
                let (payload, _) = ctx.run_stage(bytes, i as u64, augs[i]).unwrap();
                std::hint::black_box(&payload);
            }
        }
    };
    sweep();
    let samples = (batches * BATCH) as f64;
    let mut best = f64::MAX;
    for _ in 0..rounds {
        let t = Instant::now();
        sweep();
        best = best.min(t.elapsed().as_nanos() as f64);
    }
    best / samples
}

fn count_stage(dump: &crate::metrics::trace::TraceDump, stage: Stage) -> usize {
    dump.tracks
        .iter()
        .flat_map(|t| t.spans.iter())
        .filter(|s| s.stage == stage)
        .count()
}

/// Deterministic span-accounting gates, shared by the CLI bench and the
/// in-crate test.  Pure counting — no wall-clock assertions.
pub fn check_span_accounting() -> Result<()> {
    let (enc, augs) = corpus();

    // Full rate: exactly one decode + one augment span per sample.
    let tracer = Tracer::new(1.0);
    let ctx = StageCtx::new(Placement::Cpu, OUT_HW).with_tracer(tracer.clone());
    for (i, bytes) in enc.iter().enumerate() {
        ctx.run_stage(bytes, i as u64, augs[i])?;
    }
    let dump = tracer.drain();
    ensure!(
        count_stage(&dump, Stage::Decode) == BATCH && count_stage(&dump, Stage::Augment) == BATCH,
        "full-rate tracer must keep 1 decode + 1 augment span per sample, got {} + {}",
        count_stage(&dump, Stage::Decode),
        count_stage(&dump, Stage::Augment)
    );
    ensure!(dump.dropped == 0, "no ring wrap expected, got {} dropped", dump.dropped);

    // Strided sampling: rate 0.25 keeps every 4th span per stage.
    let tracer = Tracer::new(0.25);
    let ctx = StageCtx::new(Placement::Cpu, OUT_HW).with_tracer(tracer.clone());
    for _ in 0..3 {
        for (i, bytes) in enc.iter().enumerate() {
            ctx.run_stage(bytes, i as u64, augs[i])?;
        }
    }
    let want = (3 * BATCH).div_ceil(4);
    let dump = tracer.drain();
    ensure!(
        count_stage(&dump, Stage::Decode) == want,
        "stride-4 tracer must keep ceil(n/4) decode spans: {} != {want}",
        count_stage(&dump, Stage::Decode)
    );

    // Ring wrap: a tiny ring keeps the newest `cap` spans and reports
    // every overwrite as dropped.
    let cap = 16usize;
    let tracer = Tracer::with_capacity(1.0, cap);
    let ctx = StageCtx::new(Placement::Cpu, OUT_HW).with_tracer(tracer.clone());
    for _ in 0..2 {
        for (i, bytes) in enc.iter().enumerate() {
            ctx.run_stage(bytes, i as u64, augs[i])?;
        }
    }
    let pushed = 2 * BATCH * 2; // decode + augment per sample
    let dump = tracer.drain();
    ensure!(
        dump.span_count() == cap && dump.dropped == (pushed - cap) as u64,
        "wrapped ring must keep cap={cap} and drop the rest: kept {} dropped {}",
        dump.span_count(),
        dump.dropped
    );
    Ok(())
}

/// Run the microbench; optionally write `BENCH_trace.json` to `out`.
pub fn run(out: Option<&Path>) -> Result<Json> {
    check_span_accounting()?;

    let (enc, augs) = corpus();
    let off_ctx = StageCtx::new(Placement::Cpu, OUT_HW);
    let off_ns = measure(&off_ctx, &enc, &augs, 8, 4);
    // Worst case: full sampling, every span recorded.  A fresh tracer
    // per measurement keeps the ring registration out of the timed
    // region's steady state (it happens once, in the warm-up pass).
    let tracer = Tracer::new(1.0);
    let on_ctx = StageCtx::new(Placement::Cpu, OUT_HW).with_tracer(tracer.clone());
    let on_ns = measure(&on_ctx, &enc, &augs, 8, 4);
    let overhead_pct = (on_ns / off_ns - 1.0) * 100.0;
    let spans = tracer.drain().span_count();

    println!(
        "== trace overhead (cpu placement, {BATCH}x {IMG_HW}x{IMG_HW} q85 -> {OUT_HW}) =="
    );
    println!("{:<10} {:>14}", "tracer", "ns/sample");
    println!("{:<10} {:>14.0}", "off", off_ns);
    println!("{:<10} {:>14.0}", "on (1.0)", on_ns);
    println!("  overhead {overhead_pct:+.2}% (limit {TRACE_OVERHEAD_LIMIT_PCT}%), {spans} spans kept");

    ensure!(
        on_ns <= off_ns * (1.0 + TRACE_OVERHEAD_LIMIT_PCT / 100.0),
        "tracing overhead {overhead_pct:.2}% exceeds the {TRACE_OVERHEAD_LIMIT_PCT}% limit \
         ({on_ns:.0} vs {off_ns:.0} ns/sample)"
    );

    let json = Json::obj(vec![
        ("bench", Json::str("trace-overhead")),
        ("geometry", Json::str("32x 64x64x3 q85 -> 56, cpu placement")),
        ("ns_per_sample_off", Json::num(off_ns)),
        ("ns_per_sample_traced", Json::num(on_ns)),
        ("overhead_pct", Json::num(overhead_pct)),
        ("limit_pct", Json::num(TRACE_OVERHEAD_LIMIT_PCT)),
        ("spans_kept", Json::num(spans as f64)),
    ]);
    if let Some(path) = out {
        std::fs::write(path, json.pretty())?;
        println!("  wrote {}", path.display());
    }
    Ok(json)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic gates only — the 3% timing gate runs in the CI
    /// smoke step (`dpp bench trace-overhead`), where the process is
    /// quiet; under the parallel test harness a wall-clock ratio that
    /// tight would flake.
    #[test]
    fn span_accounting_is_exact() {
        check_span_accounting().unwrap();
    }

    #[test]
    fn overhead_limit_is_committed() {
        assert!(TRACE_OVERHEAD_LIMIT_PCT > 0.0 && TRACE_OVERHEAD_LIMIT_PCT <= 5.0);
    }
}
