//! `dpp bench workers` — fixed-vs-auto worker sweep (CI smoke).
//!
//! A fig-5-style row per storage tier: end-to-end throughput with fixed
//! pools of 1/2/4/8 workers next to what `--workers auto` converges to
//! (the controller's analytic fixed point, `Scenario::autoscale_workers`).
//! Everything comes out of the calibrated analytic model, so the bench
//! is deterministic — CI asserts the *shape* (auto matches the best
//! fixed point without over-provisioning) and never a wall clock.
//! Writes the rows as JSON (`BENCH_workers.json`) for the CI artifact.

use crate::config::Placement;
use crate::sim::{analytic_throughput, Scenario};
use crate::util::json::Json;
use anyhow::{ensure, Result};
use std::path::Path;

/// Fixed pool sizes swept per tier (the fig-5 x-axis, engine scale).
pub const FIXED_SWEEP: [usize; 4] = [1, 2, 4, 8];

/// One tier's sweep row.
pub struct WorkersBenchRow {
    pub storage: &'static str,
    /// `(workers, img/s)` for each fixed pool size.
    pub fixed: Vec<(usize, f64)>,
    /// Worker count `auto` converges to (fixed point, capped at 8).
    pub auto_workers: usize,
    pub auto_ips: f64,
}

impl WorkersBenchRow {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("storage", Json::str(self.storage)),
            (
                "fixed",
                Json::arr(self.fixed.iter().map(|(w, t)| {
                    Json::obj(vec![
                        ("workers", Json::num(*w as f64)),
                        ("ips", Json::num(*t)),
                    ])
                })),
            ),
            ("auto_workers", Json::num(self.auto_workers as f64)),
            ("auto_ips", Json::num(self.auto_ips)),
        ])
    }
}

/// Run the sweep; optionally write `BENCH_workers.json` to `out`.
///
/// The scenario is a fast data consumer (AlexNet, cpu placement) on one
/// GPU, where the pool genuinely binds: on the fast tiers the sweep is
/// still rising at 8 workers (prep-bound — `auto` pegs at the cap),
/// while the cold remote tier's GET rate caps the pipeline first
/// (`auto` parks below the cap at the storage match point).
pub fn run(out: Option<&Path>) -> Result<Json> {
    let (min_w, max_w) = (1usize, *FIXED_SWEEP.last().unwrap());
    let mk = |storage: &str, vcpus: usize| Scenario {
        model: "alexnet".into(),
        gpus: 1,
        vcpus,
        placement: Placement::Cpu,
        storage: storage.into(),
        net_conns: 8,
        ..Default::default()
    };
    let mut rows = Vec::new();
    println!("== workers sweep (alexnet, 1 GPU, record-cpu, img/s) ==");
    println!(
        "{:<8} {:>9} {:>9} {:>9} {:>9}  {:>12}",
        "storage", "w=1", "w=2", "w=4", "w=8", "auto"
    );
    for storage in ["ebs", "dram", "s3", "s3-cold"] {
        let fixed: Vec<(usize, f64)> = FIXED_SWEEP
            .iter()
            .map(|&w| (w, analytic_throughput(&mk(storage, w))))
            .collect();
        let auto_workers = mk(storage, max_w).autoscale_workers(min_w, max_w);
        let auto_ips = analytic_throughput(&mk(storage, auto_workers));
        println!(
            "{:<8} {:>9.0} {:>9.0} {:>9.0} {:>9.0}  {:>6.0} (w={})",
            storage,
            fixed[0].1,
            fixed[1].1,
            fixed[2].1,
            fixed[3].1,
            auto_ips,
            auto_workers
        );
        let best_fixed = fixed.iter().map(|&(_, t)| t).fold(0.0f64, f64::max);
        // The acceptance gates are model-based, so CI cannot flake:
        // auto must keep the best fixed rate...
        ensure!(
            auto_ips >= best_fixed * 0.999,
            "{storage}: auto ({auto_ips:.0}) below best fixed ({best_fixed:.0})"
        );
        // ...without over-provisioning past the smallest fixed count
        // that already achieves it.
        let smallest_best = fixed
            .iter()
            .filter(|&&(_, t)| t >= best_fixed * 0.999)
            .map(|&(w, _)| w)
            .min()
            .unwrap();
        ensure!(
            auto_workers <= smallest_best,
            "{storage}: auto parked at {auto_workers} > fixed optimum {smallest_best}"
        );
        rows.push(WorkersBenchRow { storage, fixed, auto_workers, auto_ips });
    }
    // Cross-tier shape: on at least one tier the sweep is still rising
    // at 8 workers (prep-bound — auto pegs at the cap), and on at least
    // one other it flattens early (auto parks below the cap).
    ensure!(
        rows.iter().any(|r| r.auto_workers == max_w)
            && rows.iter().any(|r| r.auto_workers < max_w),
        "sweep shape lost: every tier converged to the same pool size"
    );

    let json = Json::obj(vec![
        ("bench", Json::str("workers")),
        ("scenario", Json::str("alexnet x1 GPU record-cpu")),
        (
            "fixed_sweep",
            Json::arr(FIXED_SWEEP.iter().map(|&w| Json::num(w as f64))),
        ),
        ("rows", Json::arr(rows.iter().map(|r| r.to_json()))),
    ]);
    if let Some(path) = out {
        std::fs::write(path, json.pretty())?;
        println!("  wrote {}", path.display());
    }
    Ok(json)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workers_bench_shape_holds_without_io() {
        // The same gates `dpp bench workers` enforces, minus the file.
        let json = run(None).unwrap();
        let dump = json.dump();
        assert!(dump.contains("\"bench\":\"workers\""));
        assert!(dump.contains("\"auto_workers\""));
        // Every swept tier produced a row.
        for tier in ["ebs", "dram", "s3", "s3-cold"] {
            assert!(dump.contains(&format!("\"storage\":\"{tier}\"")), "{tier} row missing");
        }
    }
}
