//! Paper-figure reproduction harnesses.  Each function regenerates one
//! table/figure of the evaluation, prints the paper-style rows next to
//! the paper's reported values, and asserts the headline *shape* checks
//! (who wins, by roughly what factor, where crossovers fall).

use crate::autoconf::{self, Objective};
use crate::config::{Method, Placement};
use crate::pipeline::prep_cache::PrepCachePolicy;
use crate::sim::{analytic_throughput, calib, simulate, Scenario};
use anyhow::Result;
use std::path::PathBuf;

use crate::storage::Storage as _;

fn scen(model: &str, gpus: usize, vcpus: usize, method: Method, pl: Placement) -> Scenario {
    Scenario {
        model: model.into(),
        gpus,
        vcpus,
        method,
        placement: pl,
        ..Default::default()
    }
}

/// Fig. 2 — end-to-end training throughput, 5 models × 4 methods + ideal,
/// on the p3.16xlarge profile (8 GPU / 64 vCPU / EBS).
pub fn fig2() -> Result<()> {
    println!("== Fig. 2: end-to-end training performance (8xV100, 64 vCPU, img/s) ==");
    println!(
        "{:<12} {:>9} {:>10} {:>11} {:>13} {:>9}  {:>11}",
        "model", "raw-cpu", "raw-hybrid", "record-cpu", "record-hybrid", "ideal", "hyb/ideal"
    );
    let mut alexnet_ratio = 0.0;
    let mut gains = Vec::new();
    for m in ["alexnet", "shufflenet", "resnet18", "resnet50", "resnet152"] {
        let t = |method, pl| analytic_throughput(&scen(m, 8, 64, method, pl));
        let raw_cpu = t(Method::Raw, Placement::Cpu);
        let raw_hyb = t(Method::Raw, Placement::Hybrid);
        let rec_cpu = t(Method::Record, Placement::Cpu);
        let rec_hyb = t(Method::Record, Placement::Hybrid);
        let ideal = analytic_throughput(&Scenario {
            ideal: true,
            ..scen(m, 8, 64, Method::Record, Placement::Hybrid)
        });
        let ratio = rec_hyb / ideal;
        if m == "alexnet" {
            alexnet_ratio = ratio;
        }
        if matches!(m, "alexnet" | "shufflenet" | "resnet18") {
            gains.push((m, rec_hyb / rec_cpu - 1.0));
        }
        println!(
            "{m:<12} {raw_cpu:>9.0} {raw_hyb:>10.0} {rec_cpu:>11.0} {rec_hyb:>13.0} {ideal:>9.0}  {:>10.1}%",
            ratio * 100.0
        );
    }
    println!("\nchecks vs paper:");
    println!(
        "  AlexNet record-hybrid / ideal = {:.1}%   (paper: 23%)",
        alexnet_ratio * 100.0
    );
    for (m, g) in &gains {
        println!(
            "  {m}: record-hybrid vs record-cpu = +{:.0}%   (paper: +98..114% for fast consumers)",
            g * 100.0
        );
    }
    // DES spot check of the headline cell.
    let des = simulate(&Scenario {
        seconds: 30.0,
        ..scen("alexnet", 8, 64, Method::Record, Placement::Hybrid)
    });
    println!(
        "  DES spot-check alexnet record-hybrid: {:.0} img/s (analytic {:.0})",
        des.throughput_ips,
        analytic_throughput(&scen("alexnet", 8, 64, Method::Record, Placement::Hybrid))
    );
    // §2.2.3 OOM anecdote.
    let r18 = calib::model("resnet18").unwrap();
    println!(
        "  OOM model: resnet18 bs=512 FP32 hybrid fits={} (paper: OOM); bs=384 fits={}",
        calib::fits_gpu_mem(&r18, 512, true, true),
        calib::fits_gpu_mem(&r18, 384, true, true)
    );

    // Extension: multi-epoch runs with the decoded-sample cache.  Epoch 1
    // is cold (the Fig. 2 rows above); epochs >= 2 run at the steady-state
    // hit rate, so decode-bound models speed up while GPU-bound ones don't.
    println!(
        "\n== Fig. 2 extension: epoch >= 2 with a half-corpus decoded cache (record-hybrid, 24 vCPU) =="
    );
    println!(
        "{:<12} {:>9} {:>14} {:>12} {:>9}",
        "model", "epoch 1", "epoch2+ minio", "epoch2+ lru", "speedup"
    );
    let half_gb = calib::decoded_dataset_bytes() / 2.0 / 1e9;
    let mut alexnet_speedup = 0.0;
    for m in ["alexnet", "shufflenet", "resnet18", "resnet50", "resnet152"] {
        let with = |gb: f64, policy| {
            analytic_throughput(&Scenario {
                prep_cache_gb: gb,
                prep_cache_policy: policy,
                ..scen(m, 8, 24, Method::Record, Placement::Hybrid)
            })
        };
        let cold = with(0.0, PrepCachePolicy::Minio);
        let minio = with(half_gb, PrepCachePolicy::Minio);
        let lru = with(half_gb, PrepCachePolicy::Lru);
        let speedup = minio / cold;
        if m == "alexnet" {
            alexnet_speedup = speedup;
        }
        anyhow::ensure!(minio >= lru && lru >= cold - 1e-9, "{m}: cache rows inverted");
        println!("{m:<12} {cold:>9.0} {minio:>14.0} {lru:>12.0} {speedup:>8.2}x");
    }
    anyhow::ensure!(
        alexnet_speedup > 1.3,
        "decode-bound alexnet must speed up from epoch 2 on: {alexnet_speedup:.2}x"
    );
    Ok(())
}

/// Fig. 3 — *measured* per-operator latency breakdown of preprocessing a
/// single image on the CPU, on OUR pipeline (rust codec + ops), printed
/// next to the paper's percentages.
pub fn fig3(data_dir: Option<PathBuf>) -> Result<()> {
    use crate::bench::Bencher;
    use crate::ops;

    println!("== Fig. 3: per-image CPU preprocessing breakdown (measured on this host) ==");
    // Build a representative encoded image (same size class as the corpus).
    let img = crate::dataset::gen_image(&mut crate::util::rng::Rng::new(7), 5, 3, 64, 64);
    let bytes = crate::codec::encode(&img, 85)?;
    let tmp_dir =
        data_dir.unwrap_or_else(|| std::env::temp_dir().join(format!("dpp-fig3-{}", std::process::id())));
    let store = crate::storage::DirStore::new(&tmp_dir)?;
    store.write("probe.mjx", &bytes)?;

    let b = Bencher::with_budget(300);
    let read = b.run("read", || store.read("probe.mjx").unwrap());
    let entropy = b.run("entropy-decode", || crate::codec::entropy_decode(&bytes).unwrap());
    let ci = crate::codec::entropy_decode(&bytes)?;
    let xform = b.run("dequant+idct", || crate::codec::coefs_to_image(&ci));
    let decoded = crate::codec::coefs_to_image(&ci);
    let f = decoded.to_f32();
    let aug = ops::AugParams { y0: 3, x0: 4, crop_h: 56, crop_w: 56, flip: true };
    let crop = b.run("crop", || ops::crop(&f, 3, 64, 64, &aug));
    let cropped = ops::crop(&f, 3, 64, 64, &aug);
    let resize =
        b.run("resize", || ops::resize_bilinear(&cropped, 3, 56, 56, 56, 56));
    let mut flip_buf = cropped.clone();
    let flip = b.run("flip", || {
        ops::hflip(&mut flip_buf, 3, 56, 56);
    });
    let mut norm_buf = cropped.clone();
    let norm = b.run("normalize", || {
        ops::normalize(&mut norm_buf, 3, 56 * 56);
    });

    let rows = [
        ("read", read.mean_ns, calib::SHARE_READ),
        ("decode:entropy", entropy.mean_ns, calib::SHARE_ENTROPY),
        ("decode:dequant+idct", xform.mean_ns, calib::SHARE_XFORM),
        ("crop", crop.mean_ns, calib::SHARE_CROP),
        ("resize", resize.mean_ns, calib::SHARE_RESIZE),
        ("flip", flip.mean_ns, calib::SHARE_FLIP),
        ("normalize", norm.mean_ns, calib::SHARE_NORM),
    ];
    let total: f64 = rows.iter().map(|r| r.1).sum();
    println!(
        "{:<22} {:>12} {:>8}  {:>9}",
        "operator", "measured", "ours %", "paper %"
    );
    for (name, ns, paper) in rows {
        println!(
            "{name:<22} {:>12} {:>7.1}%  {:>8.1}%",
            super::harness::fmt_ns(ns),
            ns / total * 100.0,
            paper * 100.0
        );
    }
    let decode_pct = (entropy.mean_ns + xform.mean_ns) / total * 100.0;
    println!(
        "\n  total per image: {} (paper: 14.26 ms at 224x224 on a 2.3GHz vCPU)",
        super::harness::fmt_ns(total)
    );
    println!("  decode share: {decode_pct:.1}%  (paper: 47.7%)");
    println!("  preprocessing ops (non-read) share: {:.1}%  (paper: ~95%)",
        (total - read.mean_ns) / total * 100.0);

    // Extension row: the fused ROI decode against the very hot spot this
    // figure identifies — only the crop's blocks pay dequant+IDCT.
    let plan = crate::codec::DecodePlan::new(3, 64, 64, (0, 0, 40, 40), 56, 0);
    let (_, stats) = crate::codec::decode_cpu_planned(&bytes, &plan)?;
    let fused = b.run("fused-roi-decode", || {
        crate::codec::decode_cpu_planned(&bytes, &plan).unwrap()
    });
    let total_blocks = stats.blocks_idct + stats.blocks_skipped;
    println!(
        "  fused ROI decode (40x40 crop): {} — {} of {} blocks IDCT'd ({:.2}x fewer block ops)",
        super::harness::fmt_ns(fused.mean_ns),
        stats.blocks_idct,
        total_blocks,
        total_blocks as f64 / stats.blocks_idct.max(1) as f64
    );
    std::fs::remove_file(tmp_dir.join("probe.mjx")).ok();
    Ok(())
}

/// Fig. 4 — utilization traces (CPU / GPU / I/O) for AlexNet and ResNet50
/// under record-hybrid, from the discrete-event simulator.
pub fn fig4() -> Result<()> {
    println!("== Fig. 4: resource utilization under record-hybrid (DES, 60 s) ==");
    for m in ["alexnet", "resnet50"] {
        let s = Scenario { model: m.into(), seconds: 60.0, ..Default::default() };
        let out = simulate(&s);
        // Steady state = last two thirds (paper: first third is init).
        let skip = out.util_trace.len() / 3;
        let steady = &out.util_trace[skip..];
        let mean = |f: fn(&crate::metrics::UtilSample) -> f64| {
            steady.iter().map(f).sum::<f64>() / steady.len() as f64
        };
        println!(
            "{m:<10} cpu={:>5.1}%  gpu={:>5.1}%  io={:>6.1} MB/s   ({} samples)",
            mean(|u| u.cpu) * 100.0,
            mean(|u| u.device) * 100.0,
            mean(|u| u.io_mbps),
            out.util_trace.len()
        );
        for u in steady.iter().step_by(10) {
            println!(
                "    t={:>5.1}s cpu={:>5.1}% gpu={:>5.1}% io={:>6.1} MB/s",
                u.t,
                u.cpu * 100.0,
                u.device * 100.0,
                u.io_mbps
            );
        }
    }
    println!("\nchecks vs paper:");
    println!("  ResNet50: GPU ~saturated, CPU ~38%, IO ~147 MB/s (we model 110 KB/img; see EXPERIMENTS.md)");
    println!("  AlexNet: CPU util and IO must both exceed ResNet50's — the fast data consumer");
    Ok(())
}

/// Fig. 5 — throughput vs #vCPUs: AlexNet (4 GPU, hybrid vs hybrid-0) and
/// ResNet50 (8 GPU, hybrid vs cpu).
pub fn fig5() -> Result<()> {
    println!("== Fig. 5a: AlexNet, 4 GPUs — hybrid vs hybrid-0 (img/s) ==");
    println!("{:>6} {:>10} {:>10}", "vCPU", "hybrid", "hybrid-0");
    let al = |v, pl| analytic_throughput(&scen("alexnet", 4, v, Method::Record, pl));
    let mut sat_h = 0usize;
    let mut sat_h0 = 0usize;
    for v in (4..=64).step_by(4) {
        let h = al(v, Placement::Hybrid);
        let h0 = al(v, Placement::Hybrid0);
        if sat_h == 0 && (al(64, Placement::Hybrid) - h) < 1.0 {
            sat_h = v;
        }
        if sat_h0 == 0 && (al(64, Placement::Hybrid0) - h0) < 1.0 {
            sat_h0 = v;
        }
        println!("{v:>6} {h:>10.0} {h0:>10.0}");
    }
    let gain_a = al(64, Placement::Hybrid0) / al(64, Placement::Hybrid) - 1.0;
    println!(
        "  saturation: hybrid @ {sat_h} vCPU (paper: 24), hybrid-0 @ {sat_h0} vCPU (paper: 44)"
    );
    println!("  hybrid-0 gain at saturation: +{:.2}% (paper: +7.86%)", gain_a * 100.0);

    println!("\n== Fig. 5b: ResNet50, 8 GPUs — hybrid vs cpu (img/s) ==");
    println!("{:>6} {:>10} {:>10}", "vCPU", "hybrid", "cpu");
    let r50 = |v, pl| analytic_throughput(&scen("resnet50", 8, v, Method::Record, pl));
    let mut sat_h = 0usize;
    let mut sat_c = 0usize;
    for v in (4..=64).step_by(4) {
        let h = r50(v, Placement::Hybrid);
        let c = r50(v, Placement::Cpu);
        if sat_h == 0 && (r50(64, Placement::Hybrid) - h) < 1.0 {
            sat_h = v;
        }
        if sat_c == 0 && (r50(64, Placement::Cpu) - c) < 1.0 {
            sat_c = v;
        }
        println!("{v:>6} {h:>10.0} {c:>10.0}");
    }
    let gain_b = r50(64, Placement::Cpu) / r50(64, Placement::Hybrid) - 1.0;
    println!("  saturation: hybrid @ {sat_h} vCPU (paper: 16), cpu @ {sat_c} vCPU (paper: 48)");
    println!("  cpu gain at saturation: +{:.2}% (paper: +3.03%)", gain_b * 100.0);
    let s152 = scen("resnet152", 8, 64, Method::Record, Placement::Hybrid);
    println!(
        "  (resnet152 note: paper reports vCPU need dropping to 8; model gives {})",
        (analytic_throughput(&s152) * s152.cpu_cost_ms() / 1000.0).ceil()
    );

    // Extension: a warm decoded-sample cache shifts the vCPU saturation
    // point left — DRAM spent on decoded pixels substitutes for decode
    // vCPUs from epoch 2 on (the co-design the paper argues for).
    println!("\n== Fig. 5 extension: AlexNet, 4 GPUs, hybrid — cold vs warm half-corpus minio cache ==");
    println!("{:>6} {:>10} {:>10}", "vCPU", "cold", "warm");
    let half_gb = calib::decoded_dataset_bytes() / 2.0 / 1e9;
    let warm = |v| {
        analytic_throughput(&Scenario {
            prep_cache_gb: half_gb,
            ..scen("alexnet", 4, v, Method::Record, Placement::Hybrid)
        })
    };
    let mut sat_cold = 0usize;
    let mut sat_warm = 0usize;
    for v in (4..=64).step_by(4) {
        let c = al(v, Placement::Hybrid);
        let w = warm(v);
        if sat_cold == 0 && (al(64, Placement::Hybrid) - c) < 1.0 {
            sat_cold = v;
        }
        if sat_warm == 0 && (warm(64) - w) < 1.0 {
            sat_warm = v;
        }
        anyhow::ensure!(w + 1e-9 >= c, "warm epoch must never be slower");
        println!("{v:>6} {c:>10.0} {w:>10.0}");
    }
    anyhow::ensure!(
        sat_warm <= sat_cold,
        "warm cache must saturate at or before the cold sweep ({sat_warm} vs {sat_cold})"
    );
    println!("  saturation: cold @ {sat_cold} vCPU, warm @ {sat_warm} vCPU — the decoded cache \
              substitutes DRAM for decode vCPUs");

    // Extension: the elastic executor's `--workers auto` fixed point is
    // exactly the saturation knee these sweeps find by hand — the
    // controller discovers Fig. 5's answer online instead of sweeping.
    let fp = scen("alexnet", 4, 64, Method::Record, Placement::Hybrid).autoscale_workers(1, 64);
    println!(
        "  elastic `--workers auto` fixed point (alexnet, 4 GPU, hybrid): {fp} vCPU \
         (paper's Fig. 5a saturation: 24)"
    );
    anyhow::ensure!(
        (20..=28).contains(&fp),
        "auto fixed point {fp} strayed from the Fig. 5a saturation knee"
    );
    Ok(())
}

/// Fig. 6 — storage options on p3dn, 4 GPU + 48 vCPU.  The paper sweeps
/// the locally attached tiers (EBS / NVMe / DRAM); we extend the sweep
/// with the emulated remote object-store tiers (s3 / s3-cold), where
/// per-request latency and connection parallelism, not device IOPS,
/// bound the loader, plus a connection-count sweep showing the parallel
/// range-GET prefetcher hiding that latency.
pub fn fig6() -> Result<()> {
    println!("== Fig. 6: storage options, p3dn (4 GPUs, 12 vCPU each, img/s) ==");
    let t = |m: &str, storage: &str, conns: usize| {
        analytic_throughput(&Scenario {
            model: m.into(),
            gpus: 4,
            vcpus: 48,
            storage: storage.into(),
            net_conns: conns,
            p3dn: true,
            ..Default::default()
        })
    };
    println!(
        "{:<10} {:>9} {:>9} {:>9} {:>9} {:>9}  {:>10} {:>10}",
        "model", "EBS", "NVMe", "DRAM", "s3", "s3-cold", "dram/ebs", "paper"
    );
    for (m, paper) in [("resnet18", "+8.8%"), ("alexnet", "1.84x")] {
        let (ebs, nvme, dram) = (t(m, "ebs", 8), t(m, "nvme", 8), t(m, "dram", 8));
        let (s3, cold) = (t(m, "s3", 8), t(m, "s3-cold", 8));
        println!(
            "{m:<10} {ebs:>9.0} {nvme:>9.0} {dram:>9.0} {s3:>9.0} {cold:>9.0}  {:>9.2}x {paper:>10}",
            dram / ebs
        );
    }

    println!("\n== Fig. 6 extension: remote tiers, conns sweep (alexnet, img/s) ==");
    println!("{:>6} {:>9} {:>9}", "conns", "s3", "s3-cold");
    let mut prev = 0.0;
    for conns in [1usize, 2, 4, 8, 16, 32, 64] {
        let s3 = t("alexnet", "s3", conns);
        let cold = t("alexnet", "s3-cold", conns);
        anyhow::ensure!(s3 + 1e-9 >= prev, "conns must never hurt throughput");
        prev = s3;
        println!("{conns:>6} {s3:>9.0} {cold:>9.0}");
    }
    println!("\nchecks vs paper-model expectations:");
    println!("  few conns: remote tiers are first-byte-latency bound (fetch stalls)");
    println!("  enough conns: s3 approaches the local-tier rate; the prefetcher is the cure");
    let few = t("alexnet", "s3", 1);
    let many = t("alexnet", "s3", 64);
    anyhow::ensure!(many > few * 3.0, "conns sweep must show latency hiding");
    Ok(())
}

/// Table 1 — the instance catalog with prices, plus what the paper's
/// proposed auto-configuration tool recommends per model.
pub fn table1() -> Result<()> {
    println!("== Table 1: VM instances (all V100) ==");
    println!(
        "{:<15} {:>5} {:>7} {:>8}  {:>14}",
        "type", "#GPU", "#vCPU", "$/h max", "$/h @ 2 vCPU"
    );
    for i in autoconf::CATALOG {
        println!(
            "{:<15} {:>5} {:>7} {:>8.2}  {:>14.2}",
            i.name,
            i.gpus,
            i.max_vcpus,
            i.max_price,
            i.price_per_hour(2, false)
        );
    }
    println!("\n== auto-configurator recommendations (the paper's proposed tool) ==");
    for m in ["alexnet", "resnet18", "resnet50", "resnet152"] {
        for obj in [Objective::Throughput, Objective::Cost] {
            let rec = autoconf::recommend(m, obj, f64::INFINITY)?;
            println!("{m} / {obj:?}:\n  {}", rec.best.row());
        }
    }
    Ok(())
}
