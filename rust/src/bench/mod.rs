//! Benchmark infrastructure: a criterion-style timing harness (criterion
//! is unavailable offline) and the paper-figure reproduction harnesses
//! shared by `cargo bench` targets and `dpp reproduce`.

pub mod alloc;
pub mod chaos;
pub mod decode;
pub mod figures;
pub mod harness;
pub mod serve;
pub mod simd;
pub mod trace;
pub mod workers;

pub use harness::Bencher;
