//! `dpp bench alloc` — allocation/sample + ns/sample microbench for the
//! zero-copy hot path (CI smoke).
//!
//! Runs the cpu-placement stage chain + collation over a small corpus
//! twice — once on the pooled-slab path (`--slab-pool auto`), once on
//! the per-sample `Vec` path (`--slab-pool off`) — and reports, per
//! path: **allocations/sample** and **bytes/sample** (from the counting
//! global-allocator shim, `util/alloc_count.rs`) plus ns/sample.
//!
//! Gates (all enforced here and by the CI smoke step):
//! * slab path allocates ≥ 2× less per sample than the Vec path;
//! * slab allocations/sample stay within 10% of the committed baseline
//!   ([`SLAB_ALLOCS_PER_SAMPLE_BASELINE`]) — the regression guard that
//!   fails the job when a per-sample allocation sneaks back in;
//! * the engine's measured collate-copy traffic fraction agrees with
//!   `calib::COPY_SHARE` within 20% (what licenses the sim to thin the
//!   transform share by that constant);
//! * wall-clock backstop only: slab ns/sample ≤ Vec × 1.5 (the counter
//!   gates carry the regression guard; a timing gate tight enough to
//!   assert "faster" would flake on shared CI runners, so ns/sample is
//!   reported rather than tightly gated — repo precedent from the
//!   decode/workers benches, which assert no wall clock at all).
//!
//! Counters are process-global, so each path takes the **minimum over
//! several rounds** — the quietest window — to shed unrelated-thread
//! noise (there is none in the CLI run; the in-crate test runs under a
//! parallel test harness).

use crate::config::Placement;
use crate::ops;
use crate::pipeline::{collate, Payload, Sample, StageCtx, StageScratch};
use crate::sim::calib;
use crate::util::alloc_count;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::slab::SlabPool;
use anyhow::{ensure, Result};
use std::path::Path;
use std::time::Instant;

/// Committed allocations/sample baseline for the slab path.  Steady
/// state is ~4 allocations per *batch* (the samples vec, seal's
/// labels + slices vecs, the open-slab `Arc`) ≈ 0.15/sample; 1.0 leaves
/// headroom for allocator jitter while still failing loudly if even one
/// true per-sample allocation (the Vec path pays ≥ 5) reappears.
pub const SLAB_ALLOCS_PER_SAMPLE_BASELINE: f64 = 1.0;

/// Corpus/batch geometry: 64×64 q85 images into a 56×56 output, the
/// same representative shapes as `dpp bench decode`.
const BATCH: usize = 32;
const IMG_HW: usize = 64;
const OUT_HW: usize = 56;

/// One measured path.
pub struct AllocBenchRow {
    pub path: &'static str,
    pub allocs_per_sample: f64,
    pub bytes_per_sample: f64,
    pub ns_per_sample: f64,
}

impl AllocBenchRow {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("path", Json::str(self.path)),
            ("allocs_per_sample", Json::num(self.allocs_per_sample)),
            ("bytes_per_sample", Json::num(self.bytes_per_sample)),
            ("ns_per_sample", Json::num(self.ns_per_sample)),
        ])
    }
}

fn corpus() -> (Vec<Vec<u8>>, Vec<ops::AugParams>, StageCtx) {
    let enc: Vec<Vec<u8>> = (0..BATCH)
        .map(|i| {
            let img = crate::dataset::gen_image(
                &mut Rng::new(i as u64 + 1),
                (i % 5) as u16,
                3,
                IMG_HW,
                IMG_HW,
            );
            crate::codec::encode(&img, 85).unwrap()
        })
        .collect();
    let mut rng = Rng::new(0xA110C);
    let augs: Vec<ops::AugParams> = (0..BATCH)
        .map(|_| ops::sample_aug_params(&mut rng, IMG_HW as u32, IMG_HW as u32))
        .collect();
    // Full (unfused) decode: the measured traffic then matches the
    // plane+convert+augment+collate formula COPY_SHARE is derived from.
    (enc, augs, StageCtx::new(Placement::Cpu, OUT_HW))
}

/// Minimum allocs/bytes/ns over `rounds` runs of `f` (one warm-up run
/// first, so pool/scratch/channel capacities are at steady state).
fn min_over_rounds(
    rounds: usize,
    batches: usize,
    mut f: impl FnMut(),
) -> (f64, f64, f64) {
    f(); // warm-up: fills pools and scratch capacities
    let samples = (batches * BATCH) as f64;
    let (mut best_allocs, mut best_bytes, mut best_ns) = (f64::MAX, f64::MAX, f64::MAX);
    for _ in 0..rounds {
        let t = Instant::now();
        let (d, ()) = alloc_count::measure(&mut f);
        let ns = t.elapsed().as_nanos() as f64;
        best_allocs = best_allocs.min(d.allocs as f64);
        best_bytes = best_bytes.min(d.bytes as f64);
        best_ns = best_ns.min(ns);
    }
    (best_allocs / samples, best_bytes / samples, best_ns / samples)
}

/// Measure both paths; shared by the CLI bench (all gates) and the
/// in-crate test (counter gates only — no wall-clock assertions under
/// the parallel test harness).
pub fn measure_paths(rounds: usize, batches: usize) -> Result<(AllocBenchRow, AllocBenchRow)> {
    let (enc, augs, ctx) = corpus();

    // Slab path: pooled arenas + per-worker scratch, collate = seal.
    let pool = SlabPool::new(3 * OUT_HW * OUT_HW, BATCH, 2);
    let mut scratch = StageScratch::new();
    let (slab_ctx, slab_enc, slab_augs) = (ctx.clone(), enc.clone(), augs.clone());
    let slab = {
        let pool = pool.clone();
        let (a, b, ns) = min_over_rounds(rounds, batches, move || {
            for _ in 0..batches {
                let mut samples = Vec::with_capacity(BATCH);
                for (i, bytes) in slab_enc.iter().enumerate() {
                    let mut slice = pool.slice();
                    slab_ctx
                        .run_stage_into(
                            bytes,
                            i as u64,
                            slab_augs[i],
                            &mut scratch,
                            slice.as_mut_slice(),
                        )
                        .unwrap();
                    samples.push(Sample {
                        id: i as u64,
                        label: i as u16,
                        payload: Payload::Slot(slice),
                    });
                }
                let batch = collate(samples).unwrap();
                std::hint::black_box(batch.len());
                // Dropping the batch recycles its slab into the pool.
            }
        });
        AllocBenchRow { path: "slab", allocs_per_sample: a, bytes_per_sample: b, ns_per_sample: ns }
    };

    // Vec path: the historical per-sample buffers + collate memcpy.
    let (vec_ctx, vec_enc, vec_augs) = (ctx.clone(), enc.clone(), augs.clone());
    let vec = {
        let (a, b, ns) = min_over_rounds(rounds, batches, move || {
            for _ in 0..batches {
                let mut samples = Vec::with_capacity(BATCH);
                for (i, bytes) in vec_enc.iter().enumerate() {
                    let (payload, _) =
                        vec_ctx.run_stage(bytes, i as u64, vec_augs[i]).unwrap();
                    samples.push(Sample { id: i as u64, label: i as u16, payload });
                }
                let batch = collate(samples).unwrap();
                std::hint::black_box(batch.len());
            }
        });
        AllocBenchRow { path: "vec", allocs_per_sample: a, bytes_per_sample: b, ns_per_sample: ns }
    };

    Ok((slab, vec))
}

/// Collate-copy fraction of the Vec path's per-sample hot-path write
/// traffic, from the shapes this bench actually ran: u8 decode plane +
/// f32 conversion + augment output + collate memcpy.  The engine-side
/// number `calib::COPY_SHARE` must agree with (within 20%).
pub fn measured_copy_share() -> f64 {
    let plane = 3 * IMG_HW * IMG_HW; // u8 decode plane
    let conv = 3 * IMG_HW * IMG_HW * 4; // u8 → f32
    let augw = 3 * OUT_HW * OUT_HW * 4; // augment output
    let copy = augw; // collate memcpy of the same tensor
    copy as f64 / (plane + conv + augw + copy) as f64
}

/// Run the microbench; optionally write `BENCH_alloc.json` to `out`.
pub fn run(out: Option<&Path>) -> Result<Json> {
    let (slab, vec) = measure_paths(6, 4)?;

    println!("== alloc microbench (cpu placement, {BATCH}x {IMG_HW}x{IMG_HW} q85 -> {OUT_HW}) ==");
    println!(
        "{:<6} {:>16} {:>16} {:>14}",
        "path", "allocs/sample", "bytes/sample", "ns/sample"
    );
    for r in [&slab, &vec] {
        println!(
            "{:<6} {:>16.3} {:>16.0} {:>14.0}",
            r.path, r.allocs_per_sample, r.bytes_per_sample, r.ns_per_sample
        );
    }
    let ratio = vec.allocs_per_sample / slab.allocs_per_sample.max(0.01);
    println!("  slab path does {ratio:.1}x fewer hot-path allocations per sample");
    // Counter gates first (deterministic).  The ISSUE acceptance: >=2x
    // fewer hot-path allocations/sample on the cpu placement...
    ensure!(
        vec.allocs_per_sample >= 2.0 * slab.allocs_per_sample.max(0.01),
        "slab path must allocate >=2x less: slab {:.2}/sample vs vec {:.2}/sample",
        slab.allocs_per_sample,
        vec.allocs_per_sample
    );
    // ...and the regression guard against the committed baseline.
    ensure!(
        slab.allocs_per_sample <= SLAB_ALLOCS_PER_SAMPLE_BASELINE * 1.10,
        "slab allocations/sample regressed: {:.3} > baseline {} +10%",
        slab.allocs_per_sample,
        SLAB_ALLOCS_PER_SAMPLE_BASELINE
    );

    // COPY_SHARE validation: the sim thins the transform share by this
    // constant when slabs are on; the engine's measured traffic split
    // must back it within 20%.
    let measured = measured_copy_share();
    let rel = measured / calib::COPY_SHARE;
    println!(
        "  collate-copy traffic fraction: measured {measured:.4} vs calib::COPY_SHARE {:.4} (ratio {rel:.2})",
        calib::COPY_SHARE
    );
    ensure!(
        (0.8..=1.25).contains(&rel),
        "engine collate-copy fraction {measured:.4} disagrees with calib::COPY_SHARE {:.4} by >20%",
        calib::COPY_SHARE
    );
    // Wall-clock backstop last (the only non-counter assertion, so it
    // gets a wide band): the slab path is strictly less work, and the
    // counter gates above carry the real regression guard — this only
    // catches a gross slowdown (slab ≥1.5× slower would mean a real
    // bug, not scheduler noise on a shared runner).  The headline
    // "lower ns/sample" number is reported above and in the JSON.
    ensure!(
        slab.ns_per_sample <= vec.ns_per_sample * 1.5,
        "slab path grossly slower than Vec path: {:.0} vs {:.0} ns/sample",
        slab.ns_per_sample,
        vec.ns_per_sample
    );

    let json = Json::obj(vec![
        ("bench", Json::str("alloc")),
        ("geometry", Json::str("32x 64x64x3 q85 -> 56, cpu placement")),
        ("alloc_ratio", Json::num(ratio)),
        ("copy_share_measured", Json::num(measured)),
        ("copy_share_model", Json::num(calib::COPY_SHARE)),
        ("baseline_allocs_per_sample", Json::num(SLAB_ALLOCS_PER_SAMPLE_BASELINE)),
        ("rows", Json::arr([&slab, &vec].iter().map(|r| r.to_json()))),
    ]);
    if let Some(path) = out {
        std::fs::write(path, json.pretty())?;
        println!("  wrote {}", path.display());
    }
    Ok(json)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Counter gate only — the min-over-rounds ≥2× ratio, which survives
    /// foreign-thread allocator noise under the parallel test harness.
    /// The tighter absolute-baseline and wall-clock gates run in the CI
    /// smoke step (`dpp bench alloc`), where the process is quiet.
    #[test]
    fn slab_path_allocates_at_least_2x_less_than_vec_path() {
        let (slab, vec) = measure_paths(4, 1).unwrap();
        assert!(
            vec.allocs_per_sample >= 2.0 * slab.allocs_per_sample.max(0.01),
            "slab {} vs vec {}",
            slab.allocs_per_sample,
            vec.allocs_per_sample
        );
        // The Vec path genuinely pays per-sample allocations (decode
        // image + f32 convert + augment out + interpolation tables).
        assert!(vec.allocs_per_sample >= 3.0, "{}", vec.allocs_per_sample);
        let rel = measured_copy_share() / calib::COPY_SHARE;
        assert!((0.8..=1.25).contains(&rel), "copy-share ratio {rel}");
    }

    #[test]
    fn bench_json_shape() {
        // Shape-only: the timed gates run in the CI smoke step.
        let measured = measured_copy_share();
        assert!(measured > 0.0 && measured < 0.5);
        assert!(SLAB_ALLOCS_PER_SAMPLE_BASELINE >= 0.1);
    }
}
