//! `dpp bench chaos` — fault-injection resilience smoke (CI gate).
//!
//! One record shard streams through the *real* fault plane — seeded
//! `FaultyStore` under the parallel prefetcher with retry + hedging —
//! at a sweep of transient-fault rates.  Every gate is deterministic
//! (seeded faults, seeded retry jitter, counter-based arithmetic), so
//! CI asserts behavior, never a wall clock:
//!
//! * fault-free baseline: zero faults, zero retries, every record;
//! * 1% transients with retries: the epoch completes with zero
//!   trainer-visible errors and the retry overhead — extra read
//!   attempts per delivered part, the service-capacity cost that sets
//!   goodput — stays within 10% of fault-free;
//! * the analytic model agrees: end-to-end throughput at a 1% fault
//!   rate holds within 10% of fault-free at paper scale;
//! * retries off: the same seed reproduces the same failure, verbatim.
//!
//! Writes the rows as JSON (`BENCH_chaos.json`) for the CI artifact.

use crate::pipeline::source::stream_shards_resilient;
use crate::record::ShardWriter;
use crate::sim::{analytic_throughput, Scenario};
use crate::storage::prefetch::Resilience;
use crate::storage::{
    FaultProfile, FaultyStore, MemStore, PrefetchPlan, RetryPolicy, RetryStats, Storage,
};
use crate::util::json::Json;
use anyhow::{ensure, Result};
use std::path::Path;
use std::sync::Arc;

/// Records in the bench shard (sized so the part sweep sees ~100 parts).
const RECORDS: u64 = 2000;
/// Prefetch part size / connection count for the streamed reads.
const PART: usize = 8 << 10;
const CONNS: usize = 4;
/// Seed shared by the fault layer and the retry jitter.
const SEED: u64 = 7;

/// One profile's outcome.
pub struct ChaosBenchRow {
    pub profile: &'static str,
    pub retries: u32,
    /// Records delivered to the (stand-in) trainer.
    pub records: u64,
    /// Faults the seeded layer injected.
    pub faults: u64,
    /// Re-issued read attempts (the goodput overhead numerator).
    pub retried: u64,
    pub hedges_won: u64,
    /// Successful reads the backing store served (≈ delivered parts).
    pub reads: u64,
    /// First error the stream surfaced (empty when it completed).
    pub error: String,
}

impl ChaosBenchRow {
    /// Extra attempts per delivered read — the capacity the fault plane
    /// burned re-fetching, which is exactly what erodes goodput.
    pub fn overhead(&self) -> f64 {
        self.retried as f64 / self.reads.max(1) as f64
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("profile", Json::str(self.profile)),
            ("retries", Json::num(self.retries as f64)),
            ("records", Json::num(self.records as f64)),
            ("faults", Json::num(self.faults as f64)),
            ("retried", Json::num(self.retried as f64)),
            ("hedges_won", Json::num(self.hedges_won as f64)),
            ("reads", Json::num(self.reads as f64)),
            ("overhead", Json::num(self.overhead())),
            ("error", Json::str(&self.error)),
        ])
    }
}

/// Build the bench shard once and hand back its bytes.
fn shard_bytes() -> Result<Vec<u8>> {
    let dir = std::env::temp_dir().join(format!("dpp-chaos-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("shard.rec");
    let mut w = ShardWriter::create(&path)?;
    for i in 0..RECORDS {
        // Variable-length payloads so parts cut records at odd offsets.
        w.append(i, (i % 10) as u16, &vec![i as u8; 200 + (i as usize % 300)])?;
    }
    w.finish()?;
    let bytes = std::fs::read(&path)?;
    std::fs::remove_dir_all(dir).ok();
    Ok(bytes)
}

/// Stream the shard through a seeded fault layer with the given retry
/// budget; counters come back in the row.
fn run_profile(bytes: &[u8], profile: &'static str, retries: u32) -> Result<ChaosBenchRow> {
    let m = MemStore::new();
    m.write("records/shard-00000.rec", bytes.to_vec());
    let faulty = match FaultProfile::parse(profile)? {
        Some(p) => Arc::new(FaultyStore::new(m, p)),
        None => Arc::new(FaultyStore::new(m, FaultProfile::default())),
    };
    let store: Arc<dyn Storage> = faulty.clone();
    let policy = if retries > 0 {
        RetryPolicy::with_retries(retries, 30.0, SEED)
    } else {
        RetryPolicy::none()
    };
    let stats = Arc::new(RetryStats::default());
    let res = Resilience::new(policy, true, stats.clone());
    let shards = vec!["records/shard-00000.rec".to_string()];
    let mut records = 0u64;
    let streamed = stream_shards_resilient(
        store.clone(),
        &shards,
        PART,
        PrefetchPlan::new(CONNS, PART, 16 * PART),
        crate::metrics::trace::Tracer::off(),
        res,
        |_, e| Err(e), // zero skip tolerance: every record must arrive
        |_rec| {
            records += 1;
            Ok(true)
        },
    );
    let (retried, hedges_won, _give_ups) = stats.snapshot();
    Ok(ChaosBenchRow {
        profile,
        retries,
        records,
        faults: faulty.counts().total(),
        retried,
        hedges_won,
        reads: store.stats().1,
        error: streamed.err().map(|e| format!("{e:#}")).unwrap_or_default(),
    })
}

/// Run the sweep; optionally write `BENCH_chaos.json` to `out`.
pub fn run(out: Option<&Path>) -> Result<Json> {
    let bytes = shard_bytes()?;
    println!("== chaos sweep ({RECORDS} records, {CONNS}-conn prefetch, seed {SEED}) ==");
    println!(
        "{:<34} {:>7} {:>8} {:>7} {:>8} {:>9}",
        "profile", "retries", "records", "faults", "retried", "overhead"
    );
    let sweep: [(&'static str, u32); 4] = [
        ("off", 3),
        ("transient=0.01,seed=7", 3),
        ("transient=0.05,seed=7", 3),
        ("transient=0.5,seed=7", 0), // retries disabled: must fail
    ];
    let mut rows = Vec::new();
    for (profile, retries) in sweep {
        let row = run_profile(&bytes, profile, retries)?;
        println!(
            "{:<34} {:>7} {:>8} {:>7} {:>8} {:>8.1}%",
            row.profile,
            row.retries,
            row.records,
            row.faults,
            row.retried,
            row.overhead() * 100.0,
        );
        rows.push(row);
    }

    // Gate 1: the fault-free baseline is exactly clean.
    ensure!(
        rows[0].records == RECORDS && rows[0].faults == 0 && rows[0].retried == 0,
        "baseline must stream every record with zero faults/retries"
    );
    // Gate 2: at 1% transients, retry+hedging delivers the full epoch
    // with zero trainer-visible errors and holds the goodput overhead
    // (re-fetched attempts per delivered read) within 10% of fault-free.
    ensure!(
        rows[1].records == RECORDS && rows[1].error.is_empty(),
        "1% transients with retries must complete: {}",
        rows[1].error
    );
    ensure!(rows[1].faults > 0, "1% profile injected nothing — seed drift?");
    ensure!(
        rows[1].overhead() <= 0.10,
        "1% transients must stay within 10% of fault-free goodput, got {:.1}%",
        rows[1].overhead() * 100.0
    );
    // Gate 3: 5% transients still complete under the default budget.
    ensure!(
        rows[2].records == RECORDS && rows[2].error.is_empty(),
        "5% transients with retries must complete: {}",
        rows[2].error
    );
    // Gate 4: retries off fails — and replays the identical failure,
    // fault for fault, when re-run with the same seed.
    ensure!(
        !rows[3].error.is_empty() && rows[3].records < RECORDS,
        "50% transients with no retries must fail the stream"
    );
    let replay = run_profile(&bytes, rows[3].profile, 0)?;
    ensure!(
        replay.error == rows[3].error && replay.faults == rows[3].faults,
        "same seed must reproduce the same failure: {:?} vs {:?}",
        replay.error,
        rows[3].error
    );
    // Gate 5: the analytic model agrees at paper scale — 1% transients
    // under retry cost a storage-bound run under 10% end to end.
    let base = Scenario { storage: "s3".into(), net_conns: 1, ..Default::default() };
    let faulty = Scenario { fault_rate: 0.01, ..base.clone() };
    let (t0, t1) = (analytic_throughput(&base), analytic_throughput(&faulty));
    ensure!(
        t1 >= t0 * 0.9,
        "analytic: 1% faults must hold within 10% of fault-free ({t1:.0} vs {t0:.0})"
    );

    let json = Json::obj(vec![
        ("bench", Json::str("chaos")),
        ("records", Json::num(RECORDS as f64)),
        ("seed", Json::num(SEED as f64)),
        ("analytic_fault_free_ips", Json::num(t0)),
        ("analytic_faulty_ips", Json::num(t1)),
        ("rows", Json::arr(rows.iter().map(|r| r.to_json()))),
    ]);
    if let Some(path) = out {
        std::fs::write(path, json.pretty())?;
        println!("  wrote {}", path.display());
    }
    Ok(json)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_bench_gates_hold_without_io() {
        // The same gates `dpp bench chaos` enforces, minus the file.
        let json = run(None).unwrap();
        let dump = json.dump();
        assert!(dump.contains("\"bench\":\"chaos\""));
        for profile in ["off", "transient=0.01", "transient=0.5"] {
            assert!(dump.contains(profile), "{profile} row missing");
        }
    }
}
