//! Auto-configurator — the tool the paper proposes as future work (§4/§5):
//! "propose model-specific, fine-grained resource configurations for a
//! model training workflow while maintaining high throughput performance."
//!
//! Given a model and an objective (max throughput, or min $ per image),
//! it sweeps the instance catalog of Table 1 × vCPU counts × operator
//! placements × storage options through the calibrated analytic model and
//! returns the best configuration plus the runner-up table.

pub mod catalog;

pub use catalog::{Instance, CATALOG, GCLOUD_GPU_HOUR, GCLOUD_MEM_GB_HOUR, GCLOUD_VCPU_HOUR};

use crate::config::{Method, Placement};
use crate::pipeline::prep_cache::PrepCachePolicy;
use crate::sim::serve::{
    admissible, max_admissible_jobs, quota_hit_rates, shared_goodputs, standalone_goodput,
    SharedTier, TenantJob,
};
use crate::sim::{analytic_throughput, calib, Scenario};
use anyhow::{bail, Context, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// Maximize images/second.
    Throughput,
    /// Minimize $ per million images (throughput per dollar).
    Cost,
}

impl Objective {
    pub fn parse(s: &str) -> Result<Objective> {
        match s {
            "throughput" | "tput" => Ok(Objective::Throughput),
            "cost" | "dollar" | "cost-per-image" => Ok(Objective::Cost),
            _ => bail!("objective must be throughput|cost, got {s}"),
        }
    }
}

/// One evaluated configuration.
#[derive(Clone, Debug)]
pub struct Candidate {
    pub instance: &'static str,
    pub gpus: usize,
    pub vcpus: usize,
    pub placement: Placement,
    pub storage: String,
    /// Range-GET connections for remote tiers (0 = local tier).
    pub net_conns: usize,
    /// Decoded-sample cache size, GB (0 = none); DRAM for it is priced
    /// at the fine-grained memory rate.
    pub prep_cache_gb: f64,
    pub prep_cache_policy: PrepCachePolicy,
    /// Fused ROI decode on the CPU stage (bit-exact; free throughput on
    /// decode-bound configs, a no-op on hybrid ones).
    pub fused_decode: bool,
    /// Batch-slab pool on the CPU stage (bit-exact; drops the collate
    /// memcpy — cpu placement only, where the CPU hand-off is the batch).
    pub slab_pool: bool,
    pub throughput_ips: f64,
    pub price_per_hour: f64,
    pub dollars_per_mimg: f64,
}

#[derive(Clone, Debug)]
pub struct Recommendation {
    pub model: String,
    pub objective: Objective,
    pub best: Candidate,
    pub top: Vec<Candidate>,
}

/// Connection counts swept for the remote tiers (a conns choice is part
/// of the recommendation, like a vCPU count).
pub const REMOTE_CONNS_SWEEP: [usize; 5] = [4, 8, 16, 32, 64];

/// Decoded-sample cache sizes swept (GB of extra DRAM, priced at the
/// fine-grained memory rate).  The decoded ImageNet corpus is ≈ 770 GB,
/// so these are roughly third- and two-thirds-corpus caches.
pub const PREP_CACHE_GB_SWEEP: [f64; 2] = [256.0, 512.0];

/// Evaluate every (instance × vcpus × placement × storage[× conns] ×
/// prep-cache × fused-decode) configuration.  Local tiers get
/// `net_conns = 0`; the remote tiers sweep `REMOTE_CONNS_SWEEP`; the
/// decoded-sample cache sweeps sizes × policies (plus the no-cache
/// baseline); the fused ROI decode sweeps off/on where it can matter
/// (skipped for `hybrid`, where it is a modeled no-op and would only
/// duplicate rows).  The fractional decode *scale* is deliberately not an
/// autoconf axis: it trades training-data fidelity for throughput, which
/// a resource configurator has no business deciding silently.  Cache
/// DRAM is modeled exactly like the `dram` storage option's dataset
/// hosting: *additional* provisioned memory on top of the instance's own
/// (already-priced) working set, charged at the fine-grained memory
/// rate — so the tool prices a decoded cache against simply hosting the
/// encoded data on a faster tier.
pub fn enumerate(model: &str) -> Result<Vec<Candidate>> {
    calib::model(model).with_context(|| format!("unknown model {model}"))?;
    let mut cache_opts = vec![(0.0, PrepCachePolicy::Minio)];
    for gb in PREP_CACHE_GB_SWEEP {
        for policy in [PrepCachePolicy::Lru, PrepCachePolicy::Minio] {
            cache_opts.push((gb, policy));
        }
    }
    let mut out = Vec::new();
    for inst in CATALOG {
        // vCPU sweep at a 2-vCPU granularity (cloud consoles' step).
        let mut v = 2;
        while v <= inst.max_vcpus {
            for placement in [Placement::Cpu, Placement::Hybrid, Placement::Hybrid0] {
                for (storage, conns_sweep) in [
                    ("ebs", &[0usize][..]),
                    ("dram", &[0][..]),
                    ("s3", &REMOTE_CONNS_SWEEP[..]),
                    ("s3-cold", &REMOTE_CONNS_SWEEP[..]),
                ] {
                    for &conns in conns_sweep {
                        for &(cache_gb, cache_policy) in &cache_opts {
                            for fused in [false, true] {
                                // Hybrid ships whole coefficient grids:
                                // fused is a modeled no-op there, and
                                // enumerating it would only duplicate
                                // rows (crowding the top-8 table).
                                if fused && placement == Placement::Hybrid {
                                    continue;
                                }
                                for slab in [false, true] {
                                    // The slab pool only moves the model
                                    // where the CPU stage carries the
                                    // augment (and its collate copy) —
                                    // the cpu placement.  Elsewhere it
                                    // would duplicate rows.
                                    if slab && placement != Placement::Cpu {
                                        continue;
                                    }
                                    let s = Scenario {
                                        model: model.to_string(),
                                        gpus: inst.gpus,
                                        vcpus: v,
                                        method: Method::Record,
                                        placement,
                                        storage: storage.to_string(),
                                        net_conns: conns.max(1),
                                        p3dn: inst.p3dn,
                                        prep_cache_gb: cache_gb,
                                        prep_cache_policy: cache_policy,
                                        fused_decode: fused,
                                        slab_pool: slab,
                                        ..Default::default()
                                    };
                                    let t = analytic_throughput(&s);
                                    let mut price = inst.price_per_hour(v, storage == "dram");
                                    price += match storage {
                                        "s3" => catalog::s3_dataset_per_hour(),
                                        "s3-cold" => catalog::s3_cold_dataset_per_hour(),
                                        _ => 0.0,
                                    };
                                    price += cache_gb * GCLOUD_MEM_GB_HOUR;
                                    out.push(Candidate {
                                        instance: inst.name,
                                        gpus: inst.gpus,
                                        vcpus: v,
                                        placement,
                                        storage: storage.to_string(),
                                        net_conns: conns,
                                        prep_cache_gb: cache_gb,
                                        prep_cache_policy: cache_policy,
                                        fused_decode: fused,
                                        slab_pool: slab,
                                        throughput_ips: t,
                                        price_per_hour: price,
                                        dollars_per_mimg: price / (t * 3600.0) * 1e6,
                                    });
                                }
                            }
                        }
                    }
                }
            }
            v += 2;
        }
    }
    Ok(out)
}

/// `--workers auto` as a sweepable axis: the vCPU count the elastic
/// executor's controller would converge to for this (model × instance ×
/// placement × storage) cell — i.e. the fixed point of
/// [`Scenario::autoscale_workers`] over the instance's full vCPU range.
///
/// This is the static answer to the question the explicit vCPU sweep in
/// [`enumerate`] answers empirically (the fewest vCPUs that keep the
/// device fed); the cross-check test below asserts the two agree within
/// one sweep step, so the online controller and the offline
/// configurator cannot silently recommend different resource levels.
pub fn auto_vcpus(
    model: &str,
    inst: &Instance,
    placement: Placement,
    storage: &str,
    net_conns: usize,
) -> Result<usize> {
    calib::model(model).with_context(|| format!("unknown model {model}"))?;
    let s = Scenario {
        model: model.to_string(),
        gpus: inst.gpus,
        vcpus: inst.max_vcpus,
        method: Method::Record,
        placement,
        storage: storage.to_string(),
        net_conns: net_conns.max(1),
        p3dn: inst.p3dn,
        ..Default::default()
    };
    s.validate()?;
    Ok(s.autoscale_workers(1, inst.max_vcpus))
}

/// One row of the shared-tier occupancy table: the modeled per-job
/// steady state when `jobs` identical tenants share the serve tier.
#[derive(Clone, Debug)]
pub struct ServeTierRow {
    pub jobs: usize,
    /// Per-quota-slice steady-state hit rate.
    pub hit_rate: f64,
    /// Per-job goodput (items per scheduler tick).
    pub goodput_ips: f64,
    /// Fraction of the standalone goodput each tenant keeps.
    pub retention: f64,
    /// Whether admission control would accept this occupancy.
    pub admissible: bool,
}

/// Occupancy pricing for a shared multi-tenant serve tier: one row per
/// tenant count plus the admission ceiling.
#[derive(Clone, Debug)]
pub struct ServeTierPlan {
    pub floor: f64,
    /// Largest tenant count admission control accepts — the number the
    /// serve engine enforces at join time.
    pub max_jobs: usize,
    pub rows: Vec<ServeTierRow>,
}

/// Price a shared serve tier for `cap` identical tenants: how the
/// per-job hit rate and goodput degrade as the cache splits into quota
/// slices and the pool's capacity is shared, and where the admission
/// ceiling sits for the given goodput floor.
///
/// This is the configurator's answer to "how many jobs can this tier
/// carry?", built on the same closed form ([`crate::sim::serve`]) the
/// serve engine's admission control uses — the `tests/serve.rs` gate
/// cross-checks the ceiling against the engine's discrete execution,
/// and the unit test here pins the two to the same model.
pub fn plan_serve_tier(tier: &SharedTier, job: &TenantJob, floor: f64, cap: usize) -> ServeTierPlan {
    let alone = standalone_goodput(tier, job).max(f64::MIN_POSITIVE);
    let rows = (1..=cap.max(1))
        .map(|n| {
            let jobs = vec![*job; n];
            let g = shared_goodputs(tier, &jobs)[0];
            ServeTierRow {
                jobs: n,
                hit_rate: quota_hit_rates(tier, &jobs)[0],
                goodput_ips: g,
                retention: g / alone,
                admissible: admissible(tier, &jobs, floor),
            }
        })
        .collect();
    ServeTierPlan { floor, max_jobs: max_admissible_jobs(tier, job, floor, cap), rows }
}

impl ServeTierPlan {
    pub fn render(&self) -> String {
        let mut s = format!(
            "shared serve tier (floor: {:.0}% of standalone goodput) max tenants: {}\n",
            self.floor * 100.0,
            self.max_jobs
        );
        for r in &self.rows {
            s.push_str(&format!(
                "  {:>2} job(s)  hit {:.3}  goodput {:>7.1} items/tick  keeps {:>5.1}%{}\n",
                r.jobs,
                r.hit_rate,
                r.goodput_ips,
                r.retention * 100.0,
                if r.admissible { "" } else { "  (rejected)" }
            ));
        }
        s
    }
}

/// Best configuration for the model under the objective and a $/h budget.
pub fn recommend(model: &str, objective: Objective, budget_per_hour: f64) -> Result<Recommendation> {
    let mut cands: Vec<Candidate> = enumerate(model)?
        .into_iter()
        .filter(|c| c.price_per_hour <= budget_per_hour)
        .collect();
    if cands.is_empty() {
        bail!("no configuration fits budget {budget_per_hour}/h");
    }
    match objective {
        Objective::Throughput => cands.sort_by(|a, b| {
            b.throughput_ips
                .partial_cmp(&a.throughput_ips)
                .unwrap()
                // Tie-break on price: cheapest config that achieves the rate.
                .then(a.price_per_hour.partial_cmp(&b.price_per_hour).unwrap())
        }),
        Objective::Cost => {
            cands.sort_by(|a, b| a.dollars_per_mimg.partial_cmp(&b.dollars_per_mimg).unwrap())
        }
    }
    let top: Vec<Candidate> = cands.iter().take(8).cloned().collect();
    Ok(Recommendation {
        model: model.to_string(),
        objective,
        best: cands[0].clone(),
        top,
    })
}

impl Candidate {
    /// Storage column, with the recommended connection count for remote
    /// tiers ("s3:c16").
    pub fn storage_desc(&self) -> String {
        if self.net_conns > 0 {
            format!("{}:c{}", self.storage, self.net_conns)
        } else {
            self.storage.clone()
        }
    }

    /// Prep-cache column ("pc:minio512" or "-").
    pub fn cache_desc(&self) -> String {
        if self.prep_cache_gb > 0.0 {
            format!("pc:{}{:.0}", self.prep_cache_policy.name(), self.prep_cache_gb)
        } else {
            "-".to_string()
        }
    }

    pub fn row(&self) -> String {
        format!(
            "{:<14} {:>2} GPU {:>3} vCPU  {:<7} {:<12} {:<11} {:<3} {:<3} {:>9.0} img/s  ${:>6.2}/h  ${:>6.2}/Mimg",
            self.instance,
            self.gpus,
            self.vcpus,
            self.placement.name(),
            self.storage_desc(),
            self.cache_desc(),
            if self.fused_decode { "fd" } else { "-" },
            if self.slab_pool { "sl" } else { "-" },
            self.throughput_ips,
            self.price_per_hour,
            self.dollars_per_mimg,
        )
    }
}

impl Recommendation {
    pub fn render(&self) -> String {
        let mut s = format!(
            "auto-configuration for {} (objective: {:?})\n  BEST: {}\n  alternatives:\n",
            self.model, self.objective, self.best.row()
        );
        for c in self.top.iter().skip(1) {
            s.push_str(&format!("        {}\n", c.row()));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enumerate_covers_catalog() {
        let cands = enumerate("resnet50").unwrap();
        assert!(cands.len() > 100);
        for inst in CATALOG {
            assert!(cands.iter().any(|c| c.instance == inst.name));
        }
        assert!(enumerate("vgg").is_err());
    }

    #[test]
    fn throughput_objective_prefers_more_resources_for_fast_models() {
        // AlexNet is preprocessing-bound: among cache-less configs the
        // best wants many vCPUs and (per Fig. 6) DRAM-class storage.
        let cands = enumerate("alexnet").unwrap();
        let best_nocache = cands
            .iter()
            .filter(|c| c.prep_cache_gb == 0.0)
            .max_by(|a, b| a.throughput_ips.partial_cmp(&b.throughput_ips).unwrap())
            .unwrap();
        assert!(best_nocache.vcpus >= 32, "{best_nocache:?}");
        assert!(best_nocache.throughput_ips > 5000.0);
        // The overall recommendation may spend DRAM on a decoded cache
        // instead of vCPUs, but never does worse than the no-cache best —
        // and if it caches, it uses the shuffle-proof minio policy.
        let rec = recommend("alexnet", Objective::Throughput, f64::INFINITY).unwrap();
        assert!(rec.best.throughput_ips >= best_nocache.throughput_ips - 1e-9);
        if rec.best.prep_cache_gb > 0.0 {
            assert_eq!(rec.best.prep_cache_policy, PrepCachePolicy::Minio);
        }
    }

    #[test]
    fn prep_cache_sweep_prices_dram_and_prefers_minio() {
        let cands = enumerate("alexnet").unwrap();
        // Fix every other axis; vary only the cache.
        let slice: Vec<&Candidate> = cands
            .iter()
            .filter(|c| {
                c.instance == "V100-8"
                    && c.vcpus == 24
                    && c.placement == Placement::Hybrid
                    && c.storage == "ebs"
                    && !c.fused_decode
            })
            .collect();
        assert_eq!(slice.len(), 1 + 2 * PREP_CACHE_GB_SWEEP.len());
        let base = slice.iter().find(|c| c.prep_cache_gb == 0.0).unwrap();
        for &gb in &PREP_CACHE_GB_SWEEP {
            let pick = |policy: PrepCachePolicy| {
                slice
                    .iter()
                    .find(|c| c.prep_cache_gb == gb && c.prep_cache_policy == policy)
                    .unwrap()
            };
            let (minio, lru) = (pick(PrepCachePolicy::Minio), pick(PrepCachePolicy::Lru));
            // DRAM for the cache is priced identically per GB...
            let want = base.price_per_hour + gb * GCLOUD_MEM_GB_HOUR;
            assert!((minio.price_per_hour - want).abs() < 1e-9);
            assert!((lru.price_per_hour - want).abs() < 1e-9);
            // ...but minio converts it into strictly more throughput, so
            // lru candidates are dominated at every swept size.
            assert!(minio.throughput_ips > lru.throughput_ips);
            assert!(minio.throughput_ips > base.throughput_ips, "{gb} GB bought nothing");
            assert!(minio.row().contains("pc:minio"), "{}", minio.row());
        }
        // Cache DRAM is priced like dataset-DRAM hosting: additional
        // provisioned memory, identical $/GB on every instance class.
        let p32: Vec<&Candidate> = cands
            .iter()
            .filter(|c| {
                c.instance == "p3.2xlarge"
                    && c.vcpus == 8
                    && c.placement == Placement::Hybrid
                    && c.storage == "ebs"
                    && !c.fused_decode
            })
            .collect();
        assert_eq!(p32.len(), 1 + 2 * PREP_CACHE_GB_SWEEP.len());
    }

    /// `--workers auto` cross-check: the controller's fixed point must
    /// agree with the explicit vCPU sweep — the smallest swept count
    /// reaching ≥99% of the instance's peak — within one 2-vCPU step.
    #[test]
    fn auto_axis_agrees_with_explicit_worker_sweep() {
        let inst = CATALOG.iter().find(|i| i.name == "V100-8").unwrap();
        for (model, placement) in [
            ("resnet50", Placement::Hybrid),
            ("resnet50", Placement::Cpu),
            ("resnet18", Placement::Hybrid),
            ("resnet152", Placement::Hybrid),
        ] {
            let auto = auto_vcpus(model, inst, placement, "ebs", 0).unwrap();
            // Explicit sweep over the same cell (no cache, no fused —
            // the axes auto_vcpus holds at Scenario defaults).
            let cands = enumerate(model).unwrap();
            let slice: Vec<&Candidate> = cands
                .iter()
                .filter(|c| {
                    c.instance == inst.name
                        && c.placement == placement
                        && c.storage == "ebs"
                        && c.prep_cache_gb == 0.0
                        && !c.fused_decode
                        && !c.slab_pool
                })
                .collect();
            let peak = slice
                .iter()
                .map(|c| c.throughput_ips)
                .fold(0.0f64, f64::max);
            let swept = slice
                .iter()
                .filter(|c| c.throughput_ips >= 0.99 * peak)
                .map(|c| c.vcpus)
                .min()
                .unwrap();
            let diff = auto.abs_diff(swept);
            assert!(
                diff <= 2,
                "{model}/{placement:?}: auto fixed point {auto} vs swept optimum {swept}"
            );
        }
        // Unknown model / storage fail loudly.
        assert!(auto_vcpus("vgg", inst, Placement::Hybrid, "ebs", 0).is_err());
        assert!(auto_vcpus("resnet50", inst, Placement::Hybrid, "tape", 0).is_err());
    }

    #[test]
    fn cost_objective_recommends_fewer_vcpus_for_resnet50() {
        // §4: ResNet50 needs only ~2 vCPUs/GPU under hybrid — cost-optimal
        // configs should allocate far below the 8/GPU default.
        let rec = recommend("resnet50", Objective::Cost, f64::INFINITY).unwrap();
        let per_gpu = rec.best.vcpus as f64 / rec.best.gpus as f64;
        assert!(per_gpu <= 4.0, "vCPUs/GPU = {per_gpu} ({:?})", rec.best);
        // And the hybrid placement (cheapest way to feed the GPUs).
        assert_eq!(rec.best.placement, Placement::Hybrid);
    }

    #[test]
    fn paper_vcpu_reduction_claim_resnet50() {
        // §1/§4: "75% reduction in CPU resource allocation for ResNet50
        // with relatively comparable performance": 16 vs 64 vCPUs on the
        // 8-GPU instance under hybrid.
        let t = |v: usize| {
            analytic_throughput(&Scenario {
                model: "resnet50".into(),
                gpus: 8,
                vcpus: v,
                ..Default::default()
            })
        };
        let full = t(64);
        // Paper: 16 vCPUs "can adequately feed the GPUs" — our calibration
        // saturates slightly later (~21 vCPU; see EXPERIMENTS.md), so 16
        // keeps most of the rate and 24 keeps essentially all of it.
        assert!(t(16) / full > 0.70, "16 vCPU keeps {:.2} of 64-vCPU rate", t(16) / full);
        assert!(t(24) / full > 0.98, "24 vCPU keeps {:.2} of 64-vCPU rate", t(24) / full);
    }

    #[test]
    fn remote_candidates_sweep_connection_counts() {
        let cands = enumerate("alexnet").unwrap();
        let s3: Vec<&Candidate> = cands
            .iter()
            .filter(|c| c.storage == "s3" && c.instance == "V100-8" && c.vcpus == 48
                && c.placement == Placement::Hybrid && c.prep_cache_gb == 0.0
                && !c.fused_decode)
            .collect();
        assert_eq!(s3.len(), REMOTE_CONNS_SWEEP.len());
        // More connections never hurt throughput (latency hiding is
        // monotone until the caps bind).
        for w in s3.windows(2) {
            assert!(w[0].net_conns < w[1].net_conns);
            assert!(w[1].throughput_ips >= w[0].throughput_ips - 1e-9);
        }
        // Few connections leave the loader latency-bound.
        assert!(s3.last().unwrap().throughput_ips > s3[0].throughput_ips * 1.5);
        // Remote candidates carry a conns count, local candidates none.
        for c in &cands {
            assert_eq!(c.net_conns > 0, c.storage.starts_with("s3"), "{c:?}");
        }
        // Both remote tiers are enumerated, and cold storage is cheaper
        // at rest but slower at equal concurrency.
        let cold: Vec<&Candidate> = cands
            .iter()
            .filter(|c| c.storage == "s3-cold" && c.instance == "V100-8" && c.vcpus == 48
                && c.placement == Placement::Hybrid && c.prep_cache_gb == 0.0
                && !c.fused_decode)
            .collect();
        assert_eq!(cold.len(), REMOTE_CONNS_SWEEP.len());
        for (w, c) in s3.iter().zip(&cold) {
            assert_eq!(w.net_conns, c.net_conns);
            assert!(c.throughput_ips <= w.throughput_ips + 1e-9);
            assert!(c.price_per_hour < w.price_per_hour);
        }
    }

    #[test]
    fn fused_decode_axis_dominates_on_decode_bound_configs() {
        let cands = enumerate("alexnet").unwrap();
        let pick = |placement: Placement, fused: bool| {
            cands
                .iter()
                .find(|c| {
                    c.instance == "V100-8"
                        && c.vcpus == 24
                        && c.placement == placement
                        && c.storage == "ebs"
                        && c.prep_cache_gb == 0.0
                        && c.fused_decode == fused
                        && !c.slab_pool
                })
                .unwrap()
        };
        // CPU-bound cpu-placement slice: fused wins strictly at equal price.
        let (on, off) = (pick(Placement::Cpu, true), pick(Placement::Cpu, false));
        assert!(on.throughput_ips > off.throughput_ips, "{} vs {}", on.throughput_ips, off.throughput_ips);
        assert_eq!(on.price_per_hour, off.price_per_hour);
        assert!(on.row().contains(" fd "), "{}", on.row());
        assert!(on.dollars_per_mimg < off.dollars_per_mimg);
        // Hybrid ships whole coefficient grids: fused is a modeled no-op
        // there, so the sweep skips it entirely (no duplicate rows).
        assert!(
            cands.iter().filter(|c| c.placement == Placement::Hybrid).all(|c| !c.fused_decode),
            "hybrid candidates must not carry the fused axis"
        );
    }

    #[test]
    fn slab_pool_axis_dominates_on_cpu_bound_cpu_placement() {
        let cands = enumerate("alexnet").unwrap();
        let pick = |slab: bool| {
            cands
                .iter()
                .find(|c| {
                    c.instance == "V100-8"
                        && c.vcpus == 24
                        && c.placement == Placement::Cpu
                        && c.storage == "ebs"
                        && c.prep_cache_gb == 0.0
                        && !c.fused_decode
                        && c.slab_pool == slab
                })
                .unwrap()
        };
        // CPU-bound cpu-placement slice: the slab pool wins strictly at
        // equal price (it is pure removed work, like the fused decoder).
        let (on, off) = (pick(true), pick(false));
        assert!(
            on.throughput_ips > off.throughput_ips,
            "{} vs {}",
            on.throughput_ips,
            off.throughput_ips
        );
        assert_eq!(on.price_per_hour, off.price_per_hour);
        assert!(on.dollars_per_mimg < off.dollars_per_mimg);
        assert!(on.row().contains(" sl "), "{}", on.row());
        // Device placements carry no slab axis (modeled no-op — the CPU
        // hand-off there is not the final batch tensor).
        assert!(
            cands.iter().filter(|c| c.placement != Placement::Cpu).all(|c| !c.slab_pool),
            "non-cpu candidates must not carry the slab axis"
        );
        // Both axis values are enumerated for the cpu placement.
        assert!(cands.iter().any(|c| c.placement == Placement::Cpu && c.slab_pool));
        assert!(cands.iter().any(|c| c.placement == Placement::Cpu && !c.slab_pool));
    }

    #[test]
    fn s3_hosting_prices_below_dram_hosting() {
        let cands = enumerate("resnet50").unwrap();
        let pick = |storage: &str| {
            cands
                .iter()
                .find(|c| {
                    c.instance == "V100-8"
                        && c.vcpus == 16
                        && c.placement == Placement::Hybrid
                        && c.storage == storage
                        && c.prep_cache_gb == 0.0
                        && !c.fused_decode
                })
                .unwrap()
        };
        let (s3, dram, ebs) = (pick("s3"), pick("dram"), pick("ebs"));
        assert!(s3.price_per_hour < dram.price_per_hour);
        // S3 costs only the object-storage rate over EBS-hosted data.
        assert!(s3.price_per_hour - ebs.price_per_hour < 0.01);
        assert!(s3.row().contains("s3:c"), "{}", s3.row());
    }

    /// Shared-tier pricing: the occupancy table's admissible prefix is
    /// exactly the admission ceiling, degradation is monotone, and the
    /// geometry `tests/serve.rs` runs through the engine prices to the
    /// same ceiling here (5 tenants at a 0.5 floor).
    #[test]
    fn serve_tier_plan_prices_occupancy_and_matches_the_admission_ceiling() {
        let tier = SharedTier {
            cache_bytes: (4 << 20) as f64,
            capacity_units: 128.0,
            hit_cost: 1.0,
            miss_cost: 8.0,
            policy: PrepCachePolicy::Minio,
        };
        let job = TenantJob { dataset_bytes: (512 << 10) as f64, demand_items: 48.0 };
        let plan = plan_serve_tier(&tier, &job, 0.5, 8);
        assert_eq!(plan.max_jobs, 5, "the gate-2 engine geometry must price to 5 tenants");
        assert_eq!(plan.rows.len(), 8);
        for row in &plan.rows {
            assert_eq!(
                row.admissible,
                row.jobs <= plan.max_jobs,
                "row {} disagrees with the ceiling",
                row.jobs
            );
        }
        // Hit rate and goodput never improve as tenants are added.
        for w in plan.rows.windows(2) {
            assert!(w[1].hit_rate <= w[0].hit_rate + 1e-12);
            assert!(w[1].goodput_ips <= w[0].goodput_ips + 1e-9);
        }
        // One tenant keeps everything (demand-bound at 48).
        assert!((plan.rows[0].retention - 1.0).abs() < 1e-9);
        assert!((plan.rows[0].goodput_ips - 48.0).abs() < 1e-9);
        let text = plan.render();
        assert!(text.contains("max tenants: 5"), "{text}");
        assert!(text.contains("(rejected)"), "{text}");
    }

    #[test]
    fn budget_filter_applies() {
        let rec = recommend("resnet50", Objective::Throughput, 4.0).unwrap();
        assert!(rec.best.price_per_hour <= 4.0);
        assert!(rec.best.gpus == 1, "only 1-GPU instances fit $4/h");
        assert!(recommend("resnet50", Objective::Throughput, 0.5).is_err());
    }

    #[test]
    fn render_contains_rows() {
        let rec = recommend("shufflenet", Objective::Cost, f64::INFINITY).unwrap();
        let text = rec.render();
        assert!(text.contains("BEST"));
        assert!(text.lines().count() >= 4);
    }
}
