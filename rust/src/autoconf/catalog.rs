//! Table 1 of the paper: GPU VM instances on AWS EC2 and Google Cloud
//! (all V100), with the flexible-pricing model of §4.

/// Google Cloud fine-grained prices (paper §4): GPU 2.48 $/h,
/// vCPU 0.033 $/h, memory 0.0044 $/GB·h.
pub const GCLOUD_GPU_HOUR: f64 = 2.48;
pub const GCLOUD_VCPU_HOUR: f64 = 0.033;
pub const GCLOUD_MEM_GB_HOUR: f64 = 0.0044;

/// DRAM-hosting the dataset needs extra memory (ImageNet ≈ 150 GB).
pub const DATASET_DRAM_GB: f64 = 150.0;

/// S3 Standard storage price, $/GB·month (the remote-tier alternative to
/// paying the DRAM premium for the dataset).
pub const S3_GB_MONTH: f64 = 0.023;

/// S3 Standard-IA (cold) storage price, $/GB·month.
pub const S3_COLD_GB_MONTH: f64 = 0.0125;

/// Hours per month used to convert storage pricing to $/h.
pub const HOURS_PER_MONTH: f64 = 730.0;

/// $/hour to keep the ImageNet-class dataset in S3 instead of DRAM —
/// ~0.005 $/h vs the ~0.66 $/h DRAM premium, which is why the
/// auto-configurator's cost objective likes the remote tiers whenever
/// enough connections keep the loader fed.
pub fn s3_dataset_per_hour() -> f64 {
    DATASET_DRAM_GB * S3_GB_MONTH / HOURS_PER_MONTH
}

/// $/hour for the cold tier: cheaper at rest, slower to first byte.
pub fn s3_cold_dataset_per_hour() -> f64 {
    DATASET_DRAM_GB * S3_COLD_GB_MONTH / HOURS_PER_MONTH
}

#[derive(Clone, Copy, Debug)]
pub struct Instance {
    pub name: &'static str,
    pub gpus: usize,
    pub max_vcpus: usize,
    /// Full price at max vCPUs (the "< $" column of Table 1).
    pub max_price: f64,
    /// Fine-grained pricing (Google Cloud style) vs fixed-cap (EC2).
    pub fine_grained: bool,
    /// Memory included, GB (affects the DRAM-storage option).
    pub mem_gb: f64,
    /// Fig. 6-style instance profile for the storage model.
    pub p3dn: bool,
}

impl Instance {
    /// $/hour at `vcpus`, optionally with the dataset held in DRAM.
    ///
    /// Fine-grained (Google Cloud): GPU + vCPU + memory itemized.
    /// EC2: the cap price minus the vCPU discount for unallocated vCPUs
    /// (the paper's "flexible node configuration" premise).
    pub fn price_per_hour(&self, vcpus: usize, dram_dataset: bool) -> f64 {
        let vcpus = vcpus.min(self.max_vcpus);
        let extra_mem = if dram_dataset { DATASET_DRAM_GB } else { 0.0 };
        if self.fine_grained {
            self.gpus as f64 * GCLOUD_GPU_HOUR
                + vcpus as f64 * GCLOUD_VCPU_HOUR
                + (self.mem_gb + extra_mem) * GCLOUD_MEM_GB_HOUR
        } else {
            self.max_price - (self.max_vcpus - vcpus) as f64 * GCLOUD_VCPU_HOUR
                + extra_mem * GCLOUD_MEM_GB_HOUR
        }
    }
}

/// Table 1 (top: AWS EC2; bottom: Google Cloud).
pub const CATALOG: &[Instance] = &[
    Instance { name: "p3.2xlarge", gpus: 1, max_vcpus: 8, max_price: 3.06, fine_grained: false, mem_gb: 61.0, p3dn: false },
    Instance { name: "p3.16xlarge", gpus: 8, max_vcpus: 64, max_price: 24.48, fine_grained: false, mem_gb: 488.0, p3dn: false },
    Instance { name: "p3dn.24xlarge", gpus: 8, max_vcpus: 96, max_price: 31.21, fine_grained: false, mem_gb: 768.0, p3dn: true },
    Instance { name: "V100-1", gpus: 1, max_vcpus: 12, max_price: 3.22, fine_grained: true, mem_gb: 78.0, p3dn: false },
    Instance { name: "V100-4", gpus: 4, max_vcpus: 48, max_price: 12.90, fine_grained: true, mem_gb: 312.0, p3dn: false },
    Instance { name: "V100-8", gpus: 8, max_vcpus: 96, max_price: 25.80, fine_grained: true, mem_gb: 624.0, p3dn: false },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_prices_match_table1() {
        // Fine-grained instances must price out below the "< $" cap.
        for i in CATALOG {
            let p = i.price_per_hour(i.max_vcpus, false);
            assert!(
                p <= i.max_price * 1.01,
                "{}: computed {p:.2} vs cap {}",
                i.name,
                i.max_price
            );
        }
    }

    #[test]
    fn fewer_vcpus_cost_less() {
        for i in CATALOG {
            let hi = i.price_per_hour(i.max_vcpus, false);
            let lo = i.price_per_hour(2, false);
            assert!(lo < hi, "{}", i.name);
        }
    }

    #[test]
    fn s3_hosting_is_far_cheaper_than_dram_hosting() {
        let s3 = s3_dataset_per_hour();
        let dram = DATASET_DRAM_GB * GCLOUD_MEM_GB_HOUR;
        assert!(s3 < 0.01, "{s3}");
        assert!(dram / s3 > 50.0, "dram {dram} vs s3 {s3}");
    }

    #[test]
    fn dram_dataset_costs_memory() {
        let i = &CATALOG[3]; // V100-1
        let base = i.price_per_hour(8, false);
        let dram = i.price_per_hour(8, true);
        assert!((dram - base - DATASET_DRAM_GB * GCLOUD_MEM_GB_HOUR).abs() < 1e-9);
    }

    #[test]
    fn gcloud_v100_8_price_formula() {
        // 8×2.48 + 96×0.033 + 624×0.0044 = 19.84 + 3.168 + 2.7456 ≈ 25.75
        let i = CATALOG.iter().find(|i| i.name == "V100-8").unwrap();
        let p = i.price_per_hour(96, false);
        assert!((p - 25.75).abs() < 0.1, "{p}");
    }
}
