//! Training loop over the AOT train-step artifacts.
//!
//! The paper's training stage (Fig. 1 right): consume batches, run one
//! fwd/bwd+SGD step per batch.  Parameters live as XLA literals and are
//! threaded through the step artifact `(params…, images, labels, lr) ->
//! (loss, params'…)`.  Ideal mode (Fig. 2 "ideal" line) preloads a single
//! batch and reuses it, eliminating the whole preprocessing pipeline.

use crate::runtime::{lit_i32, lit_scalar, Engine};
use anyhow::{ensure, Context, Result};
use xla::Literal;

pub struct TrainSession {
    pub model: String,
    pub artifact: String,
    pub batch: usize,
    pub lr: f32,
    params: Vec<Literal>,
    pub losses: Vec<(u64, f32)>,
    pub steps: u64,
}

impl TrainSession {
    /// Load initial params and resolve the train artifact for this batch.
    pub fn new(engine: &mut Engine, model: &str, batch: usize, lr: f32) -> Result<TrainSession> {
        let artifact = engine.manifest.train_artifact(model, batch);
        engine
            .manifest
            .artifact(&artifact)
            .with_context(|| format!("no train artifact for {model} at batch {batch}"))?;
        engine.load(&artifact)?;
        let params = engine.load_params(model)?;
        Ok(TrainSession {
            model: model.to_string(),
            artifact,
            batch,
            lr,
            params,
            losses: Vec::new(),
            steps: 0,
        })
    }

    pub fn param_literals(&self) -> &[Literal] {
        &self.params
    }

    /// One SGD step. `images` is the `[B,C,OUT,OUT]` literal (possibly the
    /// direct output of a device-side preprocessing artifact — no host
    /// round-trip in that case).
    pub fn step(&mut self, engine: &mut Engine, images: Literal, labels: &[i32]) -> Result<f32> {
        ensure!(labels.len() == self.batch, "labels {} != batch {}", labels.len(), self.batch);
        let mut args = Vec::with_capacity(self.params.len() + 3);
        args.append(&mut self.params);
        args.push(images);
        args.push(lit_i32(&[self.batch], labels)?);
        args.push(lit_scalar(self.lr));
        let mut outs = engine.execute(&self.artifact, &args)?;
        ensure!(outs.len() == args.len() - 2, "train artifact output arity");
        let loss = outs.remove(0).to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?[0];
        self.params = outs;
        self.steps += 1;
        self.losses.push((self.steps, loss));
        Ok(loss)
    }

    /// Ideal-mode loop: train `steps` times on one fixed batch.
    pub fn run_ideal(
        &mut self,
        engine: &mut Engine,
        images: &[f32],
        image_shape: &[usize],
        labels: &[i32],
        steps: usize,
    ) -> Result<()> {
        for _ in 0..steps {
            let img = crate::runtime::lit_f32(image_shape, images)?;
            self.step(engine, img, labels)?;
        }
        Ok(())
    }

    /// Classification accuracy via the predict artifact (batch_main only).
    pub fn eval_accuracy(
        &mut self,
        engine: &mut Engine,
        images: &[f32],
        image_shape: &[usize],
        labels: &[i32],
    ) -> Result<f64> {
        let name = format!("predict_{}_b{}", self.model, self.batch);
        let mut args: Vec<Literal> = Vec::with_capacity(self.params.len() + 1);
        for p in &self.params {
            // Literals are opaque handles; re-upload happens inside execute.
            args.push(clone_literal(p)?);
        }
        args.push(crate::runtime::lit_f32(image_shape, images)?);
        let outs = engine.execute(&name, &args)?;
        let logits = crate::runtime::to_vec_f32(&outs[0])?;
        let classes = logits.len() / labels.len();
        let mut correct = 0usize;
        for (i, &y) in labels.iter().enumerate() {
            let row = &logits[i * classes..(i + 1) * classes];
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if argmax == y as usize {
                correct += 1;
            }
        }
        Ok(correct as f64 / labels.len() as f64)
    }

    pub fn last_loss(&self) -> Option<f32> {
        self.losses.last().map(|(_, l)| *l)
    }
}

/// Literal has no Clone in the xla crate; round-trip through raw bytes.
fn clone_literal(l: &Literal) -> Result<Literal> {
    let shape = l.array_shape().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let v = l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    crate::runtime::lit_f32(&dims, &v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::path::{Path, PathBuf};

    fn artifact_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifact_dir().join("manifest.json").exists()
    }

    /// Separable toy batch, mirroring python/tests/test_model.py.
    fn toy_batch(b: usize, hw: usize, classes: u16) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(0);
        let mut x = vec![0f32; b * 3 * hw * hw];
        let mut y = vec![0i32; b];
        for i in 0..b {
            let label = rng.gen_range(classes as u64) as u16;
            y[i] = label as i32;
            let freq = 1 + (label % 4) as usize;
            let phase = (label / 4) as f64 * std::f64::consts::PI / 4.0;
            let hot = (label as usize) % 3;
            for c in 0..3 {
                for yy in 0..hw {
                    for xx in 0..hw {
                        let stripe = (2.0 * std::f64::consts::PI * freq as f64 * xx as f64
                            / hw as f64
                            + phase)
                            .sin();
                        let amp = if c == hot { 1.0 } else { 0.0 };
                        let v = rng.normal() * 0.3 + amp * stripe;
                        x[((i * 3 + c) * hw + yy) * hw + xx] = v as f32;
                    }
                }
            }
        }
        (x, y)
    }

    #[test]
    fn train_session_reduces_loss_on_fixed_batch() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let mut eng = Engine::new(&artifact_dir()).unwrap();
        let b = eng.manifest.batch_test;
        let hw = eng.manifest.out_hw;
        let mut s = TrainSession::new(&mut eng, "resnet_t", b, 0.2).unwrap();
        let (x, y) = toy_batch(b, hw, eng.manifest.num_classes as u16);
        let shape = [b, 3, hw, hw];
        let first = {
            let img = crate::runtime::lit_f32(&shape, &x).unwrap();
            s.step(&mut eng, img, &y).unwrap()
        };
        for _ in 0..24 {
            let img = crate::runtime::lit_f32(&shape, &x).unwrap();
            s.step(&mut eng, img, &y).unwrap();
        }
        let last = s.last_loss().unwrap();
        assert!(
            last < 0.8 * first,
            "loss did not fall: {first} -> {last} ({:?})",
            s.losses
        );
        assert_eq!(s.steps, 25);
    }

    #[test]
    fn missing_model_is_an_error() {
        if !have_artifacts() {
            return;
        }
        let mut eng = Engine::new(&artifact_dir()).unwrap();
        assert!(TrainSession::new(&mut eng, "nope", 8, 0.1).is_err());
        assert!(TrainSession::new(&mut eng, "resnet_t", 999, 0.1).is_err());
    }
}
