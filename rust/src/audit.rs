//! `dpp audit` — a dependency-free source-scanning invariant linter.
//!
//! The concurrency-correctness toolkit (PR 7) rests on conventions that
//! the compiler cannot check: every `unsafe` block argues its safety,
//! every cross-thread relaxed atomic argues its ordering, and the CLI /
//! docs / report-schema triples stay in sync.  This module turns those
//! conventions into CI-enforced rules over the crate's own sources:
//!
//! 1. **safety-comment** — every `unsafe` block or `unsafe impl` carries
//!    a `SAFETY:` comment on the same line or in the comment block just
//!    above it (`unsafe fn` *declarations* are exempt: they state a
//!    caller contract, documented by their doc comment, matching
//!    `clippy::undocumented_unsafe_blocks`, which lints blocks/impls).
//! 2. **ordering-comment** — every `Ordering::Relaxed` in non-test code
//!    carries an `ordering:` justification comment the same way.
//! 3. **poison-comment** — every `.lock().unwrap()` on a mutex in
//!    non-test code carries a `poison:` comment arguing why poisoning is
//!    impossible or fatal-by-design there (the fault-tolerant data plane
//!    contains worker panics, so an unconsidered poison unwrap is how a
//!    contained panic becomes a cascade).
//! 4. **flag-parity** — every flag in `RunConfig::accepted_flags()`
//!    appears as `--flag` in both `CLI_HELP` and `DESIGN.md`.
//! 5. **report-parity** — every field of `pub struct RunReport` appears
//!    as a quoted `"field"` JSON key in the serialization in the same
//!    file.
//!
//! Scanning is purely lexical: a small state machine classifies every
//! byte of a file as code or comment (string/char literal contents count
//! as neither, so quoting a trigger token never trips a rule — which is
//! also why this module's own tests can embed violations as string
//! literals).  Per file, rules 1–2 stop at the first `#[cfg(test)]`
//! line: test code may use relaxed atomics and seeded unsafety freely.
//!
//! Diagnostics print as `file:line: [rule] message`, one per line, and a
//! non-empty finding list exits nonzero — grep-able, IDE-clickable, and
//! CI-gating without any external tooling.

use anyhow::Result;
use std::fmt;
use std::path::{Path, PathBuf};

// ---------------------------------------------------------------------------
// Lexer: split a Rust source file into per-line (code, comment) parts
// ---------------------------------------------------------------------------

/// One source line, lexed: `code` holds everything outside comments with
/// string/char-literal *contents* blanked out; `comment` holds the text
/// of line comments and block-comment segments on that line.
#[derive(Debug, Default, Clone)]
pub struct LexedLine {
    pub code: String,
    pub comment: String,
}

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Code,
    LineComment,
    /// Block comments nest in Rust; the depth rides along.
    BlockComment(u32),
    Str,
    /// Raw string with `n` trailing hashes (`r##"..."##`).
    RawStr(u32),
}

/// Lex `src` into lines.  The state machine is deliberately small: it
/// distinguishes code / comments / string-ish literals and nothing else,
/// which is all the rules need.
pub fn lex(src: &str) -> Vec<LexedLine> {
    let mut out: Vec<LexedLine> = Vec::new();
    let mut cur = LexedLine::default();
    let mut mode = Mode::Code;
    let b = src.as_bytes();
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            if mode == Mode::LineComment {
                mode = Mode::Code;
            }
            out.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match mode {
            Mode::Code => {
                if c == b'/' && b.get(i + 1) == Some(&b'/') {
                    mode = Mode::LineComment;
                    i += 2;
                    continue;
                }
                if c == b'/' && b.get(i + 1) == Some(&b'*') {
                    mode = Mode::BlockComment(1);
                    i += 2;
                    continue;
                }
                if c == b'"' {
                    mode = Mode::Str;
                    cur.code.push(' ');
                    i += 1;
                    continue;
                }
                // Raw strings: r"..."  r#"..."#  (and byte variants).
                if c == b'r' && !prev_is_ident(&cur.code) {
                    let mut j = i + 1;
                    let mut hashes = 0u32;
                    while b.get(j) == Some(&b'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if b.get(j) == Some(&b'"') {
                        mode = Mode::RawStr(hashes);
                        cur.code.push(' ');
                        i = j + 1;
                        continue;
                    }
                }
                // Char literal vs lifetime: consume 'x' or '\..' forms;
                // leave lifetimes (`'a`) as code.
                if c == b'\'' {
                    if let Some(end) = char_literal_end(b, i) {
                        cur.code.push(' ');
                        i = end;
                        continue;
                    }
                }
                cur.code.push(c as char);
                i += 1;
            }
            Mode::LineComment => {
                cur.comment.push(c as char);
                i += 1;
            }
            Mode::BlockComment(d) => {
                if c == b'/' && b.get(i + 1) == Some(&b'*') {
                    mode = Mode::BlockComment(d + 1);
                    i += 2;
                } else if c == b'*' && b.get(i + 1) == Some(&b'/') {
                    mode = if d == 1 { Mode::Code } else { Mode::BlockComment(d - 1) };
                    i += 2;
                } else {
                    cur.comment.push(c as char);
                    i += 1;
                }
            }
            Mode::Str => {
                if c == b'\\' {
                    i += 2; // escape: skip the escaped byte (incl. \")
                } else if c == b'"' {
                    mode = Mode::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            Mode::RawStr(h) => {
                if c == b'"' {
                    let mut j = i + 1;
                    let mut seen = 0u32;
                    while seen < h && b.get(j) == Some(&b'#') {
                        seen += 1;
                        j += 1;
                    }
                    if seen == h {
                        mode = Mode::Code;
                        i = j;
                        continue;
                    }
                }
                i += 1;
            }
        }
    }
    if !cur.code.is_empty() || !cur.comment.is_empty() {
        out.push(cur);
    }
    out
}

/// Does `code` end in an identifier byte?  Guards the raw-string probe
/// so `for r in ..` or `attr` is not mistaken for a raw-string start.
fn prev_is_ident(code: &str) -> bool {
    code.bytes().last().is_some_and(|c| c.is_ascii_alphanumeric() || c == b'_')
}

/// If position `i` (a `'`) starts a char literal, return the index just
/// past its closing quote; `None` means it is a lifetime.
fn char_literal_end(b: &[u8], i: usize) -> Option<usize> {
    let mut j = i + 1;
    if b.get(j) == Some(&b'\\') {
        j += 2; // escape head: \n \' \x41 \u{..}
        while j < b.len() && b[j] != b'\'' && b[j] != b'\n' {
            j += 1;
        }
        return (b.get(j) == Some(&b'\'')).then_some(j + 1);
    }
    // Plain form: exactly one scalar between quotes ('a', 'Z', '0').
    let _ = b.get(j)?;
    // Multi-byte UTF-8 scalars: advance past continuation bytes.
    j += 1;
    while j < b.len() && (b[j] & 0xC0) == 0x80 {
        j += 1;
    }
    (b.get(j) == Some(&b'\'')).then_some(j + 1)
}

// ---------------------------------------------------------------------------
// Findings
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Finding {
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

// ---------------------------------------------------------------------------
// Rules 1 + 2: justification comments for unsafe / relaxed atomics
// ---------------------------------------------------------------------------

/// How far above a flagged line a justification comment may sit.  Eight
/// lines covers every multi-line comment block in the tree while keeping
/// a stale comment from justifying half a file.
const LOOKBACK_LINES: usize = 8;

/// Does line `idx` (0-based) carry `needle` in its comment, on the line
/// itself or within the lookback window above?  The walk stops early at
/// a blank line (an unrelated comment must not leak across a gap).
fn justified(lines: &[LexedLine], idx: usize, needle: &str) -> bool {
    for back in 0..=LOOKBACK_LINES {
        let Some(j) = idx.checked_sub(back) else { break };
        let l = &lines[j];
        if back > 0 && l.code.trim().is_empty() && l.comment.trim().is_empty() {
            break; // blank line: end of the contiguous context
        }
        if l.comment.contains(needle) {
            return true;
        }
    }
    false
}

/// Index of the first line whose code carries a `#[cfg(test)]` marker —
/// rules 1–2 ignore everything from there on (test modules sit at file
/// end by convention, enforced loosely by this very cutoff).
fn test_cutoff(lines: &[LexedLine], test_attr: &str) -> usize {
    lines
        .iter()
        .position(|l| l.code.contains(test_attr))
        .unwrap_or(lines.len())
}

/// Scan one lexed file for rules 1 and 2.  `file` is only used to label
/// findings.  Needles for the trigger tokens are assembled at runtime so
/// this module's own source never contains them as code.
pub fn scan_justifications(file: &str, lines: &[LexedLine]) -> Vec<Finding> {
    let mut out = Vec::new();
    // Assembled, not written literally — otherwise this function would
    // flag (or have to exempt) itself.
    let unsafe_kw: String = ["un", "safe"].concat();
    let relaxed: String = ["Ordering::", "Rel", "axed"].concat();
    let lock_unwrap: String = ["lock().", "unwr", "ap()"].concat();
    let safety_needle: String = ["SAF", "ETY:"].concat();
    let ordering_needle: String = ["order", "ing:"].concat();
    let poison_needle: String = ["pois", "on:"].concat();
    let test_attr: String = ["#[cfg(", "test)]"].concat();
    let cutoff = test_cutoff(lines, &test_attr);
    for (idx, l) in lines.iter().enumerate().take(cutoff) {
        for start in token_positions(&l.code, &unsafe_kw) {
            // `unsafe fn` declares a contract for callers (doc-comment
            // territory); blocks and impls assert one and need SAFETY.
            let rest = l.code[start + unsafe_kw.len()..].trim_start();
            if rest.starts_with("fn ") || rest.starts_with("fn(") {
                continue;
            }
            if !justified(lines, idx, &safety_needle) {
                out.push(Finding {
                    file: file.to_string(),
                    line: idx + 1,
                    rule: "safety-comment",
                    message: format!(
                        "`{unsafe_kw}` without a `{safety_needle}` comment on this line or \
                         within {LOOKBACK_LINES} lines above"
                    ),
                });
            }
        }
        if l.code.contains(relaxed.as_str()) && !justified(lines, idx, &ordering_needle) {
            out.push(Finding {
                file: file.to_string(),
                line: idx + 1,
                rule: "ordering-comment",
                message: format!(
                    "`{relaxed}` without an `{ordering_needle}` justification on this line \
                     or within {LOOKBACK_LINES} lines above"
                ),
            });
        }
        if l.code.contains(lock_unwrap.as_str()) && !justified(lines, idx, &poison_needle) {
            out.push(Finding {
                file: file.to_string(),
                line: idx + 1,
                rule: "poison-comment",
                message: format!(
                    "`.{lock_unwrap}` without a `{poison_needle}` justification on this \
                     line or within {LOOKBACK_LINES} lines above — argue why lock \
                     poisoning is impossible (no panic under the lock) or fatal by design"
                ),
            });
        }
    }
    out
}

/// Word-boundary occurrences of `tok` in `code` (so e.g. an identifier
/// merely containing the keyword never triggers).
fn token_positions(code: &str, tok: &str) -> Vec<usize> {
    let b = code.as_bytes();
    let is_ident = |c: u8| c.is_ascii_alphanumeric() || c == b'_';
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = code[from..].find(tok) {
        let at = from + p;
        let left_ok = at == 0 || !is_ident(b[at - 1]);
        let end = at + tok.len();
        let right_ok = end >= b.len() || !is_ident(b[end]);
        if left_ok && right_ok {
            out.push(at);
        }
        from = at + tok.len().max(1);
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 3: flag parity (accepted_flags ⊆ CLI_HELP ∩ DESIGN.md)
// ---------------------------------------------------------------------------

/// Check that every accepted run flag is documented in both the help
/// text and the design document.
pub fn scan_flag_parity(design_md: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    for flag in crate::config::RunConfig::accepted_flags() {
        let needle = format!("--{flag}");
        for (doc, body) in [("CLI_HELP (src/lib.rs)", crate::CLI_HELP), ("DESIGN.md", design_md)]
        {
            if !body.contains(&needle) {
                out.push(Finding {
                    file: doc.to_string(),
                    line: 1,
                    rule: "flag-parity",
                    message: format!(
                        "accepted flag `{needle}` is not documented in {doc} \
                         (RunConfig::accepted_flags requires both)"
                    ),
                });
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 4: report field parity (RunReport struct ⊆ to_json keys)
// ---------------------------------------------------------------------------

/// Extract the field names of `pub struct RunReport { .. }` from the
/// lexed metrics source.
pub fn run_report_fields(lines: &[LexedLine]) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    let mut inside = false;
    for (idx, l) in lines.iter().enumerate() {
        let code = l.code.trim();
        if code.starts_with("pub struct RunReport") {
            inside = true;
            continue;
        }
        if inside {
            if code.starts_with('}') {
                break;
            }
            if let Some(rest) = code.strip_prefix("pub ") {
                if let Some(colon) = rest.find(':') {
                    let name = rest[..colon].trim();
                    if !name.is_empty()
                        && name.bytes().all(|c| c.is_ascii_alphanumeric() || c == b'_')
                    {
                        out.push((idx + 1, name.to_string()));
                    }
                }
            }
        }
    }
    out
}

/// Check that every `RunReport` field appears as a `"field"` string
/// literal somewhere in the metrics source (i.e. `to_json` names it as a
/// JSON key — the schema-parity direction the report consumers depend
/// on).  The needle is the quoted name rather than `("field"` because
/// rustfmt splits long `(key, value)` tuples across lines; an unquoted
/// mention (the struct declaration itself) never matches.
pub fn scan_report_parity(file: &str, src: &str, lines: &[LexedLine]) -> Vec<Finding> {
    let mut out = Vec::new();
    for (line, field) in run_report_fields(lines) {
        let key = format!("\"{field}\"");
        if !src.contains(&key) {
            out.push(Finding {
                file: file.to_string(),
                line,
                rule: "report-parity",
                message: format!(
                    "RunReport field `{field}` has no `\"{field}\"` JSON key in {file} \
                     — to_json must serialize every field"
                ),
            });
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Tree walking + entry points
// ---------------------------------------------------------------------------

fn rust_sources(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<_> =
        std::fs::read_dir(dir)?.collect::<std::io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.path());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            rust_sources(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Audit a source tree rooted at `src_dir`, with `design_md` the text of
/// DESIGN.md.  Pure function of its inputs — the CLI wrapper and the
/// self-test both call this.
pub fn audit_tree(src_dir: &Path, design_md: &str) -> Result<Vec<Finding>> {
    let mut files = Vec::new();
    rust_sources(src_dir, &mut files)?;
    let mut findings = Vec::new();
    for path in &files {
        let src = std::fs::read_to_string(path)?;
        let lines = lex(&src);
        // Findings label files relative to the crate root for stable,
        // clickable diagnostics regardless of invocation directory.
        let label = path
            .strip_prefix(src_dir.parent().unwrap_or(src_dir))
            .unwrap_or(path)
            .display()
            .to_string();
        findings.extend(scan_justifications(&label, &lines));
        if path.file_name().is_some_and(|f| f == "mod.rs")
            && path.parent().is_some_and(|d| d.file_name().is_some_and(|f| f == "metrics"))
        {
            findings.extend(scan_report_parity(&label, &src, &lines));
        }
    }
    findings.extend(scan_flag_parity(design_md));
    Ok(findings)
}

/// CLI entry: audit this crate's own sources (`src/` next to the
/// manifest) and the repo's DESIGN.md.  Prints findings to stderr and
/// returns the count, so `main` can exit nonzero without panicking.
pub fn run_self_audit() -> Result<usize> {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let src_dir = manifest.join("src");
    let design_path = manifest.join("../DESIGN.md");
    let design_md = std::fs::read_to_string(&design_path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", design_path.display()))?;
    let findings = audit_tree(&src_dir, &design_md)?;
    for f in &findings {
        eprintln!("{f}");
    }
    Ok(findings.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Trigger tokens appear below only inside string literals, which the
    // lexer blanks out of `code` — so auditing this very file stays
    // clean while the tests exercise real violations.

    #[test]
    fn lexer_separates_code_comments_and_strings() {
        let src = "let a = 1; // trailing note\nlet s = \"q // not a comment\";\n/* block\nstill block */ let b = 2;\nlet r = r#\"raw \"quote\" body\"#;\n";
        let lines = lex(src);
        assert_eq!(lines.len(), 5);
        assert!(lines[0].code.contains("let a = 1;"));
        assert_eq!(lines[0].comment.trim(), "trailing note");
        assert!(!lines[1].code.contains("not a comment"), "{:?}", lines[1]);
        assert!(lines[1].comment.is_empty());
        assert_eq!(lines[2].comment.trim(), "block");
        assert!(lines[3].code.contains("let b = 2;"));
        assert_eq!(lines[3].comment.trim(), "still block");
        assert!(!lines[4].code.contains("quote"));
    }

    #[test]
    fn lexer_handles_char_literals_and_lifetimes() {
        let src = "let c = '\"'; let d: &'a str = x; // ok\n";
        let lines = lex(src);
        // The quote inside the char literal must not open a string (which
        // would swallow the comment).
        assert_eq!(lines[0].comment.trim(), "ok");
        assert!(lines[0].code.contains("&'a str"));
    }

    #[test]
    fn undocumented_unsafe_is_flagged_with_line_number() {
        let src = "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
        let findings = scan_justifications("x.rs", &lex(src));
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert_eq!(findings[0].line, 2);
        assert_eq!(findings[0].rule, "safety-comment");
    }

    #[test]
    fn documented_unsafe_passes_and_unsafe_fn_is_exempt() {
        let src = "// SAFETY: p is valid by contract.\nunsafe { *p }\nunsafe fn g() {}\n";
        let findings = scan_justifications("x.rs", &lex(src));
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn unjustified_relaxed_is_flagged_and_justified_passes() {
        let bad = "x.fetch_add(1, Ordering::Relaxed);\n";
        let f = scan_justifications("x.rs", &lex(bad));
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!((f[0].line, f[0].rule), (1, "ordering-comment"));
        let good = "// ordering: Relaxed — telemetry only.\nx.fetch_add(1, Ordering::Relaxed);\n";
        assert!(scan_justifications("x.rs", &lex(good)).is_empty());
    }

    #[test]
    fn unjustified_lock_unwrap_is_flagged_and_poison_comment_passes() {
        let bad = "let g = self.names.lock().unwrap();\n";
        let f = scan_justifications("x.rs", &lex(bad));
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!((f[0].line, f[0].rule), (1, "poison-comment"));
        let good =
            "// poison: only Vec ops run under this lock.\nlet g = self.names.lock().unwrap();\n";
        assert!(scan_justifications("x.rs", &lex(good)).is_empty());
        // Non-mutex unwraps are someone else's business.
        let unrelated = "let v = maybe.unwrap();\n";
        assert!(scan_justifications("x.rs", &lex(unrelated)).is_empty());
    }

    #[test]
    fn justification_does_not_leak_across_blank_lines_or_window() {
        let far = format!(
            "// SAFETY: far away.\n{}unsafe {{ *p }}\n",
            "let pad = 0;\n".repeat(LOOKBACK_LINES + 1)
        );
        assert_eq!(scan_justifications("x.rs", &lex(&far)).len(), 1);
        let gap = "// SAFETY: above a gap.\n\nunsafe { *p }\n";
        assert_eq!(scan_justifications("x.rs", &lex(gap)).len(), 1, "blank line must cut context");
    }

    #[test]
    fn test_modules_are_exempt() {
        let src = "fn main() {}\n#[cfg(test)]\nmod tests {\n    fn t() { unsafe { x() } }\n}\n";
        assert!(scan_justifications("x.rs", &lex(src)).is_empty());
    }

    #[test]
    fn quoted_tokens_do_not_trigger() {
        let src = "let s = \"unsafe { Ordering::Relaxed }\"; let t = 1;\n";
        assert!(scan_justifications("x.rs", &lex(src)).is_empty());
    }

    #[test]
    fn run_report_fields_are_parsed_and_parity_checked() {
        let src = "pub struct RunReport {\n    pub images: u64,\n    pub ghost: f64,\n}\nfn j() { let _ = (\"images\", 1); }\n";
        let lines = lex(src);
        let fields: Vec<String> = run_report_fields(&lines).into_iter().map(|(_, f)| f).collect();
        assert_eq!(fields, vec!["images", "ghost"]);
        let findings = scan_report_parity("m.rs", src, &lines);
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert!(findings[0].message.contains("ghost"));
        assert_eq!(findings[0].line, 3);
    }

    #[test]
    fn flag_parity_holds_against_real_design_md() {
        let design = std::fs::read_to_string(
            Path::new(env!("CARGO_MANIFEST_DIR")).join("../DESIGN.md"),
        )
        .expect("DESIGN.md at repo root");
        let findings = scan_flag_parity(&design);
        assert!(findings.is_empty(), "{findings:#?}");
    }

    /// The acceptance gate: the tree audits clean.  Running it as a unit
    /// test means plain `cargo test` already enforces every rule; the CI
    /// `dpp audit` step re-checks via the CLI for a grep-able log.
    #[test]
    fn repo_tree_audits_clean() {
        let n = run_self_audit().expect("audit runs");
        assert_eq!(n, 0, "tree has audit findings (printed on stderr above)");
    }

    #[test]
    fn seeded_violation_in_tree_shape_is_caught() {
        // End-to-end through audit_tree: a temp tree with one dirty file.
        let dir = std::env::temp_dir().join(format!("dpp-audit-test-{}", std::process::id()));
        let src = dir.join("src");
        std::fs::create_dir_all(&src).unwrap();
        std::fs::write(src.join("dirty.rs"), "fn f() { unsafe { x() } }\n").unwrap();
        let findings = audit_tree(&src, "").unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
        // The seeded unsafe plus every flag-parity miss against the empty
        // design doc — the unsafe one carries the file:line shape.
        let dirty: Vec<_> =
            findings.iter().filter(|f| f.rule == "safety-comment").collect();
        assert_eq!(dirty.len(), 1, "{findings:#?}");
        assert!(dirty[0].file.ends_with("dirty.rs"));
        assert_eq!(dirty[0].line, 1);
        assert!(findings.iter().any(|f| f.rule == "flag-parity"));
    }
}
