//! RecFile: the record-file format of the paper's "record preprocessing"
//! method (Fig. 1 white circles ①–⑤).
//!
//! Many small raw files are appended offline into a few large sequential
//! shards, turning random reads into sequential ones.  Each shard gets a
//! sidecar index for bounds/labels, so runtime readers can stream chunks
//! sequentially *or* address individual records.
//!
//! Shard layout:
//! ```text
//!   header   : "DPPREC1\0" (8 bytes) | record_count u32 | reserved u32
//!   record   : len u32 | id u64 | label u16 | fnv u32 | payload[len]
//! ```
//! Index (`.idx`) layout: header "DPPIDX1\0", then per record:
//! `id u64 | offset u64 | len u32 | label u16 | pad u16`.

use anyhow::{bail, ensure, Context, Result};
use std::fs::File;
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

pub const REC_MAGIC: &[u8; 8] = b"DPPREC1\0";
pub const IDX_MAGIC: &[u8; 8] = b"DPPIDX1\0";
pub const REC_HEADER_LEN: u64 = 16;
const REC_META_LEN: usize = 4 + 8 + 2 + 4; // len + id + label + fnv

/// FNV-1a checksum (self-contained; no crc crate offline).
pub fn fnv1a(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811C9DC5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x01000193);
    }
    h
}

#[derive(Clone, Debug, PartialEq)]
pub struct RecordMeta {
    pub id: u64,
    pub label: u16,
    pub offset: u64,
    pub len: u32,
}

#[derive(Clone, Debug)]
pub struct Record {
    pub id: u64,
    pub label: u16,
    pub payload: Vec<u8>,
}

/// Writes one shard + its index.
pub struct ShardWriter {
    data: BufWriter<File>,
    path: PathBuf,
    metas: Vec<RecordMeta>,
    offset: u64,
}

impl ShardWriter {
    pub fn create(path: &Path) -> Result<Self> {
        let mut f = BufWriter::new(File::create(path).with_context(|| format!("create {path:?}"))?);
        f.write_all(REC_MAGIC)?;
        f.write_all(&0u32.to_le_bytes())?; // patched in finish()
        f.write_all(&0u32.to_le_bytes())?;
        Ok(ShardWriter { data: f, path: path.to_path_buf(), metas: Vec::new(), offset: REC_HEADER_LEN })
    }

    pub fn append(&mut self, id: u64, label: u16, payload: &[u8]) -> Result<()> {
        ensure!(payload.len() <= u32::MAX as usize, "payload too large");
        let len = payload.len() as u32;
        self.data.write_all(&len.to_le_bytes())?;
        self.data.write_all(&id.to_le_bytes())?;
        self.data.write_all(&label.to_le_bytes())?;
        self.data.write_all(&fnv1a(payload).to_le_bytes())?;
        self.data.write_all(payload)?;
        self.metas.push(RecordMeta { id, label, offset: self.offset, len });
        self.offset += (REC_META_LEN + payload.len()) as u64;
        Ok(())
    }

    pub fn record_count(&self) -> usize {
        self.metas.len()
    }

    pub fn bytes_written(&self) -> u64 {
        self.offset
    }

    /// Flush data, patch the header count, and write the `.idx` sidecar.
    pub fn finish(mut self) -> Result<Vec<RecordMeta>> {
        self.data.flush()?;
        let mut f = self.data.into_inner()?;
        f.seek(SeekFrom::Start(8))?;
        f.write_all(&(self.metas.len() as u32).to_le_bytes())?;
        f.sync_all().ok();

        let idx_path = idx_path_for(&self.path);
        let mut idx = BufWriter::new(File::create(&idx_path)?);
        idx.write_all(IDX_MAGIC)?;
        for m in &self.metas {
            idx.write_all(&m.id.to_le_bytes())?;
            idx.write_all(&m.offset.to_le_bytes())?;
            idx.write_all(&m.len.to_le_bytes())?;
            idx.write_all(&m.label.to_le_bytes())?;
            idx.write_all(&0u16.to_le_bytes())?;
        }
        idx.flush()?;
        Ok(self.metas)
    }
}

pub fn idx_path_for(shard: &Path) -> PathBuf {
    shard.with_extension("idx")
}

/// Load an `.idx` sidecar.
pub fn read_index(idx_bytes: &[u8]) -> Result<Vec<RecordMeta>> {
    ensure!(idx_bytes.len() >= 8, "truncated index");
    if &idx_bytes[..8] != IDX_MAGIC {
        bail!("bad index magic");
    }
    let body = &idx_bytes[8..];
    ensure!(body.len() % 24 == 0, "ragged index file: {} bytes", body.len());
    let mut metas = Vec::with_capacity(body.len() / 24);
    for rec in body.chunks_exact(24) {
        metas.push(RecordMeta {
            id: u64::from_le_bytes(rec[0..8].try_into().unwrap()),
            offset: u64::from_le_bytes(rec[8..16].try_into().unwrap()),
            len: u32::from_le_bytes(rec[16..20].try_into().unwrap()),
            label: u16::from_le_bytes(rec[20..22].try_into().unwrap()),
        });
    }
    Ok(metas)
}

/// Parse one record at `buf[pos..]`; returns (record, bytes consumed).
pub fn parse_record(buf: &[u8], pos: usize) -> Result<(Record, usize)> {
    ensure!(buf.len() >= pos + REC_META_LEN, "truncated record header");
    let len = u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()) as usize;
    let id = u64::from_le_bytes(buf[pos + 4..pos + 12].try_into().unwrap());
    let label = u16::from_le_bytes(buf[pos + 12..pos + 14].try_into().unwrap());
    let want_fnv = u32::from_le_bytes(buf[pos + 14..pos + 18].try_into().unwrap());
    let body_at = pos + REC_META_LEN;
    ensure!(buf.len() >= body_at + len, "truncated record payload");
    let payload = buf[body_at..body_at + len].to_vec();
    if fnv1a(&payload) != want_fnv {
        bail!("record {id}: checksum mismatch");
    }
    Ok((Record { id, label, payload }, REC_META_LEN + len))
}

/// Parse a whole in-memory shard (header + records).
pub fn parse_shard(buf: &[u8]) -> Result<Vec<Record>> {
    ensure!(buf.len() >= REC_HEADER_LEN as usize, "truncated shard");
    if &buf[..8] != REC_MAGIC {
        bail!("bad shard magic");
    }
    let count = u32::from_le_bytes(buf[8..12].try_into().unwrap()) as usize;
    let mut out = Vec::with_capacity(count);
    let mut pos = REC_HEADER_LEN as usize;
    while out.len() < count {
        let (rec, used) = parse_record(buf, pos)?;
        pos += used;
        out.push(rec);
    }
    Ok(out)
}

/// Streaming reader over one shard file: reads `chunk_size` bytes at a
/// time (sequential I/O), yielding records — the paper's runtime steps
/// ④–⑤ (read into memory, partition into chunks, decode).
pub struct ShardReader<R: Read> {
    src: R,
    buf: Vec<u8>,
    valid: usize,
    pos: usize,
    remaining: usize,
    chunk_size: usize,
    started: bool,
}

impl<R: Read> ShardReader<R> {
    pub fn new(src: R, chunk_size: usize) -> Self {
        ShardReader {
            src,
            buf: Vec::new(),
            valid: 0,
            pos: 0,
            remaining: 0,
            chunk_size: chunk_size.max(REC_HEADER_LEN as usize),
            started: false,
        }
    }

    fn fill(&mut self) -> Result<usize> {
        // Compact consumed prefix, then read one more chunk.
        if self.pos > 0 {
            self.buf.drain(..self.pos);
            self.valid -= self.pos;
            self.pos = 0;
        }
        let old = self.buf.len();
        self.buf.resize(old + self.chunk_size, 0);
        let n = self.src.read(&mut self.buf[old..])?;
        self.buf.truncate(old + n);
        self.valid = self.buf.len();
        Ok(n)
    }

    fn start(&mut self) -> Result<()> {
        while self.valid < REC_HEADER_LEN as usize {
            if self.fill()? == 0 {
                bail!("shard shorter than header");
            }
        }
        if &self.buf[..8] != REC_MAGIC {
            bail!("bad shard magic");
        }
        self.remaining = u32::from_le_bytes(self.buf[8..12].try_into().unwrap()) as usize;
        self.pos = REC_HEADER_LEN as usize;
        self.started = true;
        Ok(())
    }

    pub fn next_record(&mut self) -> Result<Option<Record>> {
        match self.next_event()? {
            None => Ok(None),
            Some(RecordEvent::Record(rec)) => Ok(Some(rec)),
            Some(RecordEvent::Skipped { err, .. }) => bail!("{err}"),
        }
    }

    /// Fault-tolerant read: a complete-but-corrupt record (checksum
    /// mismatch) is *skipped* by its framed length instead of wedging
    /// the stream — the caller decides whether the skip fits its budget.
    /// Truncation (a frame that can never complete) still errors: there
    /// is no resync point to hop to.
    pub fn next_event(&mut self) -> Result<Option<RecordEvent>> {
        if !self.started {
            self.start()?;
        }
        if self.remaining == 0 {
            return Ok(None);
        }
        loop {
            match parse_record(&self.buf[..self.valid], self.pos) {
                Ok((rec, used)) => {
                    self.pos += used;
                    self.remaining -= 1;
                    return Ok(Some(rec));
                }
                Err(e) => {
                    // A fully-framed record that still fails to parse is
                    // corrupt payload, not missing bytes: hop over the
                    // frame (its length header tells us how far) and
                    // report the skip.
                    if let Some((id, used)) = framed_corrupt(&self.buf[..self.valid], self.pos) {
                        self.pos += used;
                        self.remaining -= 1;
                        return Ok(Some(RecordEvent::Skipped { id, err: format!("{e:#}") }));
                    }
                    if self.fill()? == 0 {
                        // Cannot make progress: genuinely truncated/corrupt.
                        parse_record(&self.buf[..self.valid], self.pos)?;
                        unreachable!();
                    }
                }
            }
        }
    }
}

/// One event from a fault-tolerant shard stream: a good record, or a
/// note that one corrupt record was hopped over.
#[derive(Clone, Debug)]
pub enum RecordEvent {
    Record(Record),
    /// A complete frame whose payload failed its checksum.  `id` is the
    /// id the (possibly corrupt) header claims.
    Skipped { id: u64, err: String },
}

/// If `buf[pos..]` holds a *complete* record frame whose payload fails
/// its checksum, return `(claimed id, frame length)` so a reader can hop
/// past it.  Incomplete frames return `None` (more bytes may fix them).
fn framed_corrupt(buf: &[u8], pos: usize) -> Option<(u64, usize)> {
    if buf.len() < pos + REC_META_LEN {
        return None;
    }
    let len = u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()) as usize;
    let id = u64::from_le_bytes(buf[pos + 4..pos + 12].try_into().unwrap());
    let want_fnv = u32::from_le_bytes(buf[pos + 14..pos + 18].try_into().unwrap());
    let body_at = pos + REC_META_LEN;
    if buf.len() < body_at + len {
        return None;
    }
    if fnv1a(&buf[body_at..body_at + len]) == want_fnv {
        return None;
    }
    Some((id, REC_META_LEN + len))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::io::Cursor;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("dpp-rec-{name}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn make_payload(rng: &mut Rng, n: usize) -> Vec<u8> {
        (0..n).map(|_| rng.next_u32() as u8).collect()
    }

    #[test]
    fn write_parse_roundtrip() {
        let dir = tmpdir("rt");
        let shard = dir.join("s0.rec");
        let mut rng = Rng::new(1);
        let mut w = ShardWriter::create(&shard).unwrap();
        let mut want = Vec::new();
        for i in 0..50u64 {
            let n = (rng.gen_range(2000) + 1) as usize;
            let p = make_payload(&mut rng, n);
            w.append(i, (i % 16) as u16, &p).unwrap();
            want.push((i, (i % 16) as u16, p));
        }
        let metas = w.finish().unwrap();
        assert_eq!(metas.len(), 50);

        let buf = std::fs::read(&shard).unwrap();
        let recs = parse_shard(&buf).unwrap();
        assert_eq!(recs.len(), 50);
        for (r, (id, label, p)) in recs.iter().zip(&want) {
            assert_eq!((r.id, r.label, &r.payload), (*id, *label, p));
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn index_roundtrip_matches_offsets() {
        let dir = tmpdir("idx");
        let shard = dir.join("s0.rec");
        let mut rng = Rng::new(2);
        let mut w = ShardWriter::create(&shard).unwrap();
        for i in 0..20u64 {
            w.append(i * 7, 3, &make_payload(&mut rng, 100 + i as usize)).unwrap();
        }
        let metas = w.finish().unwrap();
        let idx = std::fs::read(idx_path_for(&shard)).unwrap();
        let loaded = read_index(&idx).unwrap();
        assert_eq!(metas, loaded);

        // Random access via index: read record 13 directly.
        let buf = std::fs::read(&shard).unwrap();
        let m = &loaded[13];
        let (rec, _) = parse_record(&buf, m.offset as usize).unwrap();
        assert_eq!(rec.id, 13 * 7);
        assert_eq!(rec.payload.len(), 113);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn chunked_reader_streams_all_records() {
        let dir = tmpdir("chunk");
        let shard = dir.join("s0.rec");
        let mut rng = Rng::new(3);
        let mut w = ShardWriter::create(&shard).unwrap();
        let mut lens = Vec::new();
        for i in 0..40u64 {
            let n = (rng.gen_range(5000) + 1) as usize;
            let p = make_payload(&mut rng, n);
            w.append(i, 0, &p).unwrap();
            lens.push(n);
        }
        w.finish().unwrap();
        let bytes = std::fs::read(&shard).unwrap();
        // Chunk smaller than many records forces refills mid-record.
        for chunk in [64usize, 1000, 1 << 20] {
            let mut r = ShardReader::new(Cursor::new(bytes.clone()), chunk);
            let mut got = 0;
            while let Some(rec) = r.next_record().unwrap() {
                assert_eq!(rec.payload.len(), lens[got]);
                got += 1;
            }
            assert_eq!(got, 40, "chunk={chunk}");
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn corruption_detected() {
        let dir = tmpdir("fnv");
        let shard = dir.join("s0.rec");
        let mut w = ShardWriter::create(&shard).unwrap();
        w.append(1, 0, b"hello world payload").unwrap();
        w.finish().unwrap();
        let mut buf = std::fs::read(&shard).unwrap();
        let n = buf.len();
        buf[n - 3] ^= 0xFF; // flip a payload byte
        assert!(parse_shard(&buf).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn next_event_hops_over_a_corrupt_record() {
        let dir = tmpdir("hop");
        let shard = dir.join("s0.rec");
        let mut w = ShardWriter::create(&shard).unwrap();
        for i in 0..5u64 {
            w.append(i, 0, &vec![i as u8; 64]).unwrap();
        }
        let metas = w.finish().unwrap();
        let mut buf = std::fs::read(&shard).unwrap();
        // Flip a payload byte in the middle record (id 2).
        buf[metas[2].offset as usize + REC_META_LEN + 10] ^= 0xFF;

        // Strict reader: wedges exactly at the corrupt record.
        let mut strict = ShardReader::new(Cursor::new(buf.clone()), 64);
        assert_eq!(strict.next_record().unwrap().unwrap().id, 0);
        assert_eq!(strict.next_record().unwrap().unwrap().id, 1);
        let err = strict.next_record().unwrap_err();
        assert!(format!("{err:#}").contains("checksum mismatch"), "{err:#}");

        // Tolerant reader: reports the skip, then keeps streaming.
        let mut ids = Vec::new();
        let mut skips = Vec::new();
        let mut r = ShardReader::new(Cursor::new(buf), 64);
        while let Some(ev) = r.next_event().unwrap() {
            match ev {
                RecordEvent::Record(rec) => ids.push(rec.id),
                RecordEvent::Skipped { id, err } => {
                    assert!(err.contains("checksum mismatch"), "{err}");
                    skips.push(id);
                }
            }
        }
        assert_eq!(ids, vec![0, 1, 3, 4]);
        assert_eq!(skips, vec![2]);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn fnv_known_vector() {
        assert_eq!(fnv1a(b""), 0x811C9DC5);
        assert_eq!(fnv1a(b"a"), 0xE40C292C);
    }
}
