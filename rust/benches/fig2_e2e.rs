//! `cargo bench --bench fig2_e2e` — regenerates the paper's Fig. 2 
//! via the shared harness in dpp::bench::figures (also: `dpp reproduce`).

fn main() {
    dpp::bench::figures::fig2().expect("fig2 harness failed");
}
