//! `cargo bench --bench decode_microbench` — counter-based decode
//! microbench: blocks dequant+IDCT'd and ns/image for the full vs fused
//! ROI vs fused+scaled paths (also: `dpp bench decode`).

fn main() {
    dpp::bench::decode::run(None).expect("decode microbench failed");
}
