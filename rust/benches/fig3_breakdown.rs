//! `cargo bench --bench fig3_breakdown` — Fig. 3: measured per-operator
//! latency breakdown of CPU preprocessing on THIS host, printed next to
//! the paper's percentages (also: `dpp reproduce --fig 3`).

fn main() {
    dpp::bench::figures::fig3(None).expect("fig3 harness failed");
}
