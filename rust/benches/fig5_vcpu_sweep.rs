//! `cargo bench --bench fig5_vcpu_sweep` — regenerates the paper's Fig. 5 
//! via the shared harness in dpp::bench::figures (also: `dpp reproduce`).

fn main() {
    dpp::bench::figures::fig5().expect("fig5 harness failed");
}
