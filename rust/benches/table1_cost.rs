//! `cargo bench --bench table1_cost` — regenerates the paper's table1 
//! via the shared harness in dpp::bench::figures (also: `dpp reproduce`).

fn main() {
    dpp::bench::figures::table1().expect("table1 harness failed");
}
