//! `cargo bench --bench ablations` — ablations over the design choices
//! DESIGN.md calls out: record chunk size, shuffle-buffer size, codec
//! quality, and cache budget.

use dpp::codec;
use dpp::dataset;
use dpp::pipeline::shuffle::ShuffleBuffer;
use dpp::record::{parse_shard, ShardWriter};
use dpp::storage::{CachedStore, MemStore, Storage};
use dpp::util::rng::Rng;
use std::io::Cursor;
use std::time::Instant;

fn main() {
    let dir = std::env::temp_dir().join(format!("dpp-abl-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    // Shared corpus: 256 encoded images.
    let payloads: Vec<Vec<u8>> = (0..256)
        .map(|i| {
            codec::encode(&dataset::gen_image(&mut Rng::new(i), (i % 16) as u16, 3, 64, 64), 85)
                .unwrap()
        })
        .collect();

    // ---- ablation 1: record chunk size vs streaming rate -----------------
    println!("== ablation: record chunk size (sequential streaming rate) ==");
    let shard_path = dir.join("abl.rec");
    {
        let mut w = ShardWriter::create(&shard_path).unwrap();
        for (i, p) in payloads.iter().enumerate() {
            w.append(i as u64, 0, p).unwrap();
        }
        w.finish().unwrap();
    }
    let bytes = std::fs::read(&shard_path).unwrap();
    for chunk in [4 << 10, 64 << 10, 256 << 10, 1 << 20, 4 << 20] {
        let t = Instant::now();
        let mut n = 0;
        for _ in 0..20 {
            let mut r = dpp::record::ShardReader::new(Cursor::new(&bytes[..]), chunk);
            while r.next_record().unwrap().is_some() {
                n += 1;
            }
        }
        let rate = (bytes.len() * 20) as f64 / t.elapsed().as_secs_f64() / 1e6;
        println!("  chunk {:>9}: {rate:>8.0} MB/s ({n} records)", dpp::util::human_bytes(chunk as u64));
    }

    // ---- ablation 2: shuffle-buffer size vs randomness --------------------
    println!("== ablation: shuffle-buffer size vs randomness (mean displacement, n=4096) ==");
    let n = 4096usize;
    for cap in [1usize, 16, 64, 256, 1024] {
        let mut sb = ShuffleBuffer::new(cap, Rng::new(1));
        let mut out = Vec::with_capacity(n);
        for i in 0..n as u64 {
            if let Some(v) = sb.push(i) {
                out.push(v);
            }
        }
        out.extend(sb.drain());
        let disp: f64 = out
            .iter()
            .enumerate()
            .map(|(pos, &v)| (pos as f64 - v as f64).abs())
            .sum::<f64>()
            / n as f64;
        println!("  cap {cap:>5}: mean displacement {disp:>8.1} (uniform would be ~{:.0})", n as f64 / 3.0);
    }

    // ---- ablation 3: codec quality vs size & decode time ------------------
    println!("== ablation: MJX quality vs compressed size & decode time ==");
    let img = dataset::gen_image(&mut Rng::new(7), 3, 3, 64, 64);
    for q in [30u8, 50, 70, 85, 95] {
        let enc = codec::encode(&img, q).unwrap();
        let t = Instant::now();
        for _ in 0..200 {
            codec::decode_cpu(&enc).unwrap();
        }
        let us = t.elapsed().as_secs_f64() / 200.0 * 1e6;
        let dec = codec::decode_cpu(&enc).unwrap();
        let mse: f64 = img
            .data
            .iter()
            .zip(&dec.data)
            .map(|(&a, &b)| ((a as f64) - (b as f64)).powi(2))
            .sum::<f64>()
            / img.data.len() as f64;
        println!(
            "  q{q:>3}: {:>6} B ({:>4.1}% of raw)  decode {us:>6.1} µs  mse {mse:>6.1}",
            enc.len(),
            enc.len() as f64 / img.data.len() as f64 * 100.0
        );
    }

    // ---- ablation 4: cache budget vs hit rate (2 epochs, raw reads) -------
    println!("== ablation: cache budget vs hit rate (2 epochs over 256 objects) ==");
    let total: usize = payloads.iter().map(|p| p.len()).sum();
    for frac in [0.25, 0.5, 1.0, 2.0] {
        let budget = (total as f64 * frac) as usize;
        let m = MemStore::new();
        for (i, p) in payloads.iter().enumerate() {
            m.write(&format!("img/{i:06}.mjx"), p.clone());
        }
        let c = CachedStore::new(m, budget);
        for _ in 0..2 {
            for i in 0..payloads.len() {
                c.read(&format!("img/{i:06}.mjx")).unwrap();
            }
        }
        println!(
            "  budget {:>9} ({frac:>4.2}x dataset): hit rate {:>5.1}%",
            dpp::util::human_bytes(budget as u64),
            c.hit_rate() * 100.0
        );
    }

    std::fs::remove_dir_all(dir).ok();
}
