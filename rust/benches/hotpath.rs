//! `cargo bench --bench hotpath` — micro-benchmarks of every hot-path
//! component (deliverable (e)): codec encode/decode, augmentation ops,
//! record streaming, channel overhead, PJRT artifact execution, and the
//! end-to-end train step.  §Perf of EXPERIMENTS.md tracks these numbers
//! across optimization iterations.

use dpp::bench::Bencher;
use dpp::codec;
use dpp::dataset;
use dpp::ops;
use dpp::record::ShardWriter;
use dpp::util::rng::Rng;

fn main() {
    let b = Bencher::with_budget(500);
    let img = dataset::gen_image(&mut Rng::new(1), 4, 3, 64, 64);
    let px = 3.0 * 64.0 * 64.0;

    println!("== codec (one 3x64x64 image) ==");
    let bytes = codec::encode(&img, 85).unwrap();
    println!(
        "  encoded size: {} ({}% of raw)",
        bytes.len(),
        bytes.len() * 100 / img.data.len()
    );
    b.run("encode q85", || codec::encode(&img, 85).unwrap()).print_rate(px, "px");
    b.run("decode_cpu (entropy+dequant+idct)", || codec::decode_cpu(&bytes).unwrap())
        .print_rate(px, "px");
    b.run("entropy_decode only (hybrid CPU half)", || codec::entropy_decode(&bytes).unwrap())
        .print_rate(px, "px");
    let ci = codec::entropy_decode(&bytes).unwrap();
    b.run("dequant+idct only (offloadable half)", || codec::coefs_to_image(&ci))
        .print_rate(px, "px");

    println!("== augmentation ops (3x64x64 -> 3x56x56) ==");
    let f = img.to_f32();
    let aug = ops::AugParams { y0: 2, x0: 3, crop_h: 58, crop_w: 60, flip: true };
    let mut out = vec![0f32; 3 * 56 * 56];
    b.run("augment_fused", || {
        ops::augment_fused(&f, 3, 64, 64, &aug, 56, 56, &mut out);
    })
    .print_rate(px, "px");
    let mut rng = Rng::new(2);
    b.run("sample_aug_params", || ops::sample_aug_params(&mut rng, 64, 64)).print();

    println!("== record format ==");
    let dir = std::env::temp_dir().join(format!("dpp-hotpath-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let shard = dir.join("bench.rec");
    let payloads: Vec<Vec<u8>> = (0..64).map(|i| {
        codec::encode(&dataset::gen_image(&mut Rng::new(i), (i % 16) as u16, 3, 64, 64), 85)
            .unwrap()
    }).collect();
    {
        let mut w = ShardWriter::create(&shard).unwrap();
        for (i, p) in payloads.iter().enumerate() {
            w.append(i as u64, 0, p).unwrap();
        }
        w.finish().unwrap();
    }
    let shard_bytes = std::fs::read(&shard).unwrap();
    let total = shard_bytes.len() as f64;
    b.run("parse_shard (64 records)", || dpp::record::parse_shard(&shard_bytes).unwrap())
        .print_rate(total, "B");

    println!("== pipeline primitives ==");
    let (tx, rx) = dpp::pipeline::channel::bounded::<u64>(1024);
    b.run("channel send+recv (uncontended)", || {
        tx.send(1).unwrap();
        rx.recv().unwrap()
    })
    .print();
    let hybrid_ctx = dpp::pipeline::StageCtx::new(dpp::config::Placement::Hybrid, 56);
    b.run("run_stage hybrid (entropy only)", || {
        hybrid_ctx.run_stage(&payloads[0], 0, aug).unwrap()
    })
    .print_rate(1.0, "img");
    let cpu_ctx = dpp::pipeline::StageCtx::new(dpp::config::Placement::Cpu, 56);
    b.run("run_stage cpu (full decode+augment)", || {
        cpu_ctx.run_stage(&payloads[0], 0, aug).unwrap()
    })
    .print_rate(1.0, "img");

    // PJRT path (skipped if artifacts are missing).
    let adir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if adir.join("manifest.json").exists() {
        println!("== PJRT runtime (CPU client) ==");
        let mut eng = dpp::runtime::Engine::new(&adir).unwrap();
        let bsz = eng.manifest.batch_test;
        let bh = eng.manifest.img_hw / 8;
        let coefs_v = vec![0.5f32; bsz * 3 * bh * bh * 64];
        let q = [4.0f32; 64];
        let aug_rows: Vec<f32> = (0..bsz).flat_map(|_| [2., 3., 58., 60., 1., 0.]).collect();
        let fused = eng.manifest.fused_artifact(bsz);
        eng.load(&fused).unwrap();
        b.run("fused_pre_b8 execute (decode+augment HLO)", || {
            let c = dpp::runtime::lit_f32(&[bsz, 3, bh, bh, 8, 8], &coefs_v).unwrap();
            let ql = dpp::runtime::lit_f32(&[8, 8], &q).unwrap();
            let a = dpp::runtime::lit_f32(&[bsz, 6], &aug_rows).unwrap();
            eng.execute(&fused, &[c, ql, a]).unwrap()
        })
        .print_rate(bsz as f64, "img");

        let mut sess =
            dpp::trainer::TrainSession::new(&mut eng, "resnet_t", bsz, 0.1).unwrap();
        let hw = eng.manifest.out_hw;
        let imgs = vec![0.1f32; bsz * 3 * hw * hw];
        let labels: Vec<i32> = (0..bsz as i32).map(|i| i % 16).collect();
        b.run("train step resnet_t b8 (fwd+bwd+sgd HLO)", || {
            let il = dpp::runtime::lit_f32(&[bsz, 3, hw, hw], &imgs).unwrap();
            sess.step(&mut eng, il, &labels).unwrap()
        })
        .print_rate(bsz as f64, "img");
    } else {
        println!("(artifacts missing — run `make artifacts` for PJRT benches)");
    }

    println!("== simulator ==");
    let scen = dpp::sim::Scenario { model: "resnet50".into(), seconds: 20.0, ..Default::default() };
    b.run("analytic_throughput", || dpp::sim::analytic_throughput(&scen)).print();
    let b2 = Bencher::with_budget(900);
    b2.run("DES 20 sim-seconds (resnet50 hybrid)", || dpp::sim::simulate(&scen)).print();

    std::fs::remove_dir_all(dir).ok();
}
