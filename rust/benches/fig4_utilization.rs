//! `cargo bench --bench fig4_utilization` — regenerates the paper's Fig. 4 
//! via the shared harness in dpp::bench::figures (also: `dpp reproduce`).

fn main() {
    dpp::bench::figures::fig4().expect("fig4 harness failed");
}
