//! `cargo bench --bench fig6_storage` — regenerates the paper's Fig. 6 
//! via the shared harness in dpp::bench::figures (also: `dpp reproduce`).

fn main() {
    dpp::bench::figures::fig6().expect("fig6 harness failed");
}
